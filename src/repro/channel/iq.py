"""Fronthaul IQ spectrogram synthesis for UL slots.

Grid: (2 [I/Q], 273 PRB * 12 subcarriers = 3276, 14 OFDM symbols) — the
paper's Table I input. Per-RE complex samples with power from: the UE's
allocated transmission, the interference source (scenario-shaped footprint),
and the thermal noise floor.
"""
from __future__ import annotations

import numpy as np

N_PRB = 273
N_SC = N_PRB * 12  # 3276
N_SYM = 14


def footprint(scenario: str, rng: np.random.Generator) -> np.ndarray:
    """(N_SC, N_SYM) in [0,1]: where the interference lands on the grid."""
    m = np.zeros((N_SC, N_SYM), np.float32)
    if scenario == "none":
        return m
    if scenario == "jamming":  # barrage: wide band, bursty in time
        f0 = rng.integers(0, N_SC // 4)
        f1 = rng.integers(3 * N_SC // 4, N_SC)
        sym = rng.random(N_SYM) < 0.8
        m[f0:f1, sym] = 1.0
    elif scenario == "cci":  # neighbouring UE: PRB-block granular
        n_blocks = rng.integers(2, 6)
        for _ in range(n_blocks):
            p0 = rng.integers(N_PRB // 8, N_PRB)  # avoids the low PRBs
            w = rng.integers(8, 40)
            m[p0 * 12:(p0 + w) * 12] = 1.0
    elif scenario == "tdd":  # aggressor DL symbols overlap victim UL
        m[:, 8:] = 1.0  # trailing symbols of the slot
        m[: N_SC // 10] = 0.0  # victim's protected low PRBs
    else:
        raise ValueError(scenario)
    return m


def spectrogram(int_dbm: float, scenario: str, load_ratio: float,
                rng: np.random.Generator, n_sc: int = N_SC,
                n_sym: int = N_SYM) -> np.ndarray:
    """(2, n_sc, n_sym) float32 IQ grid (reduced n_sc for unit tests)."""
    fp = footprint(scenario, rng)
    if n_sc != N_SC:
        idx = np.linspace(0, N_SC - 1, n_sc).astype(int)
        fp = fp[idx]
    alloc = np.zeros((n_sc, n_sym), np.float32)
    n_alloc = max(1, int(load_ratio * n_sc))
    alloc[:n_alloc] = 1.0  # gNB fills grants from the low PRBs upward
    sig_p = 10 ** (-10.0 / 10) * alloc
    int_p = 10 ** (np.asarray(int_dbm) / 10) * fp
    noise_p = 10 ** (-35.0 / 10)
    std = np.sqrt((sig_p + int_p + noise_p) / 2.0)
    iq = rng.normal(size=(2, n_sc, n_sym)).astype(np.float32) * std[None]
    return iq


def to_dbfs(iq: np.ndarray) -> np.ndarray:
    """Log-power image (the CNN sees spectrogram magnitudes)."""
    p = iq[0] ** 2 + iq[1] ** 2
    return (10 * np.log10(np.maximum(p, 1e-12))).astype(np.float32)
