"""Fronthaul IQ spectrogram synthesis for UL slots.

Grid: (2 [I/Q], 273 PRB * 12 subcarriers = 3276, 14 OFDM symbols) — the
paper's Table I input. Per-RE complex samples with power from: the UE's
allocated transmission, the interference source (scenario-shaped footprint),
and the thermal noise floor.
"""
from __future__ import annotations

import numpy as np

N_PRB = 273
N_SC = N_PRB * 12  # 3276
N_SYM = 14


def footprint_batch(scenario: str, m: int, rng: np.random.Generator,
                    n_sc: int = N_SC, n_sym: int = N_SYM) -> np.ndarray:
    """(m, n_sc, n_sym) in [0,1]: where the interference lands on the
    grid, for m slots of one scenario drawn in one shot.

    Footprint geometry is always sampled in full-resolution (N_SC)
    coordinates and evaluated at the ``n_sc`` retained subcarrier rows, so
    reduced-width test grids see the same spatial statistics as the full
    grid after row subsampling."""
    sc = (np.arange(n_sc) if n_sc == N_SC
          else np.linspace(0, N_SC - 1, n_sc).astype(int))  # (n_sc,) rows
    if scenario == "none":
        return np.zeros((m, n_sc, n_sym), np.float32)
    if scenario == "jamming":  # barrage: wide band, bursty in time
        f0 = rng.integers(0, N_SC // 4, m)
        f1 = rng.integers(3 * N_SC // 4, N_SC, m)
        band = (sc[None] >= f0[:, None]) & (sc[None] < f1[:, None])
        sym = rng.random((m, n_sym)) < 0.8
        return (band[:, :, None] & sym[:, None, :]).astype(np.float32)
    if scenario == "cci":  # neighbouring UE: PRB-block granular
        prb = sc // 12  # blocks start above N_PRB // 8: avoids the low PRBs
        max_blocks = 5  # n_blocks ~ U{2..5}; extra draws masked out
        n_blocks = rng.integers(2, 6, m)
        p0 = rng.integers(N_PRB // 8, N_PRB, (m, max_blocks))
        w = rng.integers(8, 40, (m, max_blocks))
        live = np.arange(max_blocks)[None] < n_blocks[:, None]
        hit = (live[:, :, None] & (prb[None, None] >= p0[:, :, None])
               & (prb[None, None] < (p0 + w)[:, :, None])).any(axis=1)
        return np.broadcast_to(hit[:, :, None].astype(np.float32),
                               (m, n_sc, n_sym)).copy()
    if scenario == "tdd":  # aggressor DL symbols overlap victim UL
        one = ((sc[:, None] >= N_SC // 10)  # victim's protected low PRBs
               & (np.arange(n_sym)[None] >= 8)).astype(np.float32)
        return np.broadcast_to(one[None], (m, n_sc, n_sym)).copy()
    raise ValueError(scenario)


def spectrogram_batch(int_dbm: np.ndarray, scenario, load_ratio,
                      rng: np.random.Generator, n_sc: int = N_SC,
                      n_sym: int = N_SYM) -> np.ndarray:
    """(m, 2, n_sc, n_sym) float32 IQ grids for m UL slots in one shot.

    ``scenario``: one name or an (m,) array of per-slot names (mixed-fleet
    batches draw each scenario group's footprints together)."""
    x = np.atleast_1d(np.asarray(int_dbm, float))
    m = len(x)
    lr = np.broadcast_to(np.asarray(load_ratio, float), (m,))
    scen = np.broadcast_to(np.asarray(scenario), (m,))
    fp = np.empty((m, n_sc, n_sym), np.float32)
    for s in np.unique(scen):
        idx = np.flatnonzero(scen == s)
        fp[idx] = footprint_batch(str(s), len(idx), rng, n_sc, n_sym)
    alloc = np.zeros((m, n_sc, n_sym), np.float32)
    n_alloc = np.maximum(1, (lr * n_sc).astype(int))
    alloc[np.arange(n_sc)[None] < n_alloc[:, None]] = 1.0  # low PRBs upward
    sig_p = 10 ** (-10.0 / 10) * alloc
    int_p = 10 ** (x / 10)[:, None, None] * fp
    noise_p = 10 ** (-35.0 / 10)
    std = np.sqrt((sig_p + int_p + noise_p) / 2.0)
    iq = rng.normal(size=(m, 2, n_sc, n_sym)).astype(np.float32)
    return iq * std[:, None]


def spectrogram(int_dbm: float, scenario: str, load_ratio: float,
                rng: np.random.Generator, n_sc: int = N_SC,
                n_sym: int = N_SYM) -> np.ndarray:
    """(2, n_sc, n_sym) float32 IQ grid (shim over the batched path)."""
    return spectrogram_batch(np.asarray([int_dbm], float), scenario,
                             load_ratio, rng, n_sc, n_sym)[0]


def to_dbfs(iq: np.ndarray) -> np.ndarray:
    """Log-power image (the CNN sees spectrogram magnitudes)."""
    p = iq[0] ** 2 + iq[1] ** 2
    return (10 * np.log10(np.maximum(p, 1e-12))).astype(np.float32)
