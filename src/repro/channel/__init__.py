from repro.channel import iq, kpm, scenarios, throughput  # noqa: F401
