"""KPM trace synthesis (gNB-side, 0.1 s reporting period, Near-RT RIC xApp).

Feature sets:
  KPMS_7   — Minovski et al. [8]: RSRP, RSRQ, SINR, P_a, RI, CQI, CRI
  KPMS_8   — the paper's additions: PUSCH-SINR, TPC, UL-MCS, UL-BLER,
             HARQ-RV0..3 counters
  KPMS_15  — both.

Key modelled effect (Fig. 2b): under LOW UL load the UE's few allocated PRBs
dodge the interference, so the 15 numerical KPMs stay nominal while the max
*achievable* throughput collapses — only the IQ spectrogram reveals it.
"""
from __future__ import annotations

import numpy as np

from repro.channel import throughput as tp

KPMS_7 = ["rsrp", "rsrq", "sinr", "p_a", "ri", "cqi", "cri"]
KPMS_8 = ["pusch_sinr", "tpc", "ul_mcs", "ul_bler",
          "harq_rv0", "harq_rv1", "harq_rv2", "harq_rv3"]
KPMS_15 = KPMS_7 + KPMS_8


# How much of the interference footprint overlaps the low PRBs that carry a
# small grant: barrage jamming hits them too; CCI blocks dodge them; TDD
# cross-link hits trailing symbols only.
SCENARIO_OVERLAP = {"none": 0.0, "jamming": 0.8, "cci": 0.35, "tdd": 0.6}


def kpm_step(int_dbm: float, load_ratio: float, rng: np.random.Generator,
             harq_state: np.ndarray, scenario: str = "cci") -> dict:
    """One 0.1s KPM report. load_ratio: allocated/total PRBs in (0,1]."""
    n = lambda s: rng.normal(0.0, s)
    # DL-side metrics: unaffected by UL interference (paper's 7-KPM baseline
    # fails exactly because of this)
    out = {
        "rsrp": -85.0 + n(1.0),
        "rsrq": -10.5 + n(0.5),
        "sinr": 22.0 + n(1.0),
        "p_a": -3.0 + n(0.2),
        "ri": 2.0 + (rng.random() < 0.05),
        "cqi": 13.0 + np.round(n(0.6)),
        "cri": 1.0,
    }
    # UL metrics see the interference hitting the *allocated* PRBs: full
    # grant => full footprint; small grant => scenario-dependent overlap.
    overlap = SCENARIO_OVERLAP.get(scenario, 0.3)
    visible = max(np.clip((load_ratio - 0.15) / 0.85, 0.0, 1.0), overlap)
    eff_int = int_dbm * visible + (-60.0) * (1 - visible)
    out["pusch_sinr"] = float(tp.sinr_db(np.array(eff_int))) + n(0.8)
    out["tpc"] = float(tp.tpc_boost_db(np.array(eff_int))) + n(0.3)
    out["ul_mcs"] = float(tp.mcs_index(np.array(eff_int)))
    b = float(tp.bler(np.array(eff_int)))
    out["ul_bler"] = np.clip(b + n(0.02), 0, 1)
    # HARQ RV counters: rv0 = new TBs, rv1 = first retx (rv0 * BLER), rv2/3
    # appear when BLER saturates (the paper's OOC-zone estimator signal)
    tbs = rng.poisson(80 * load_ratio + 1)
    rv1 = rng.binomial(tbs, min(b, 1.0))
    rv2 = rng.binomial(rv1, min(b, 1.0))
    rv3 = rng.binomial(rv2, min(b, 1.0))
    harq_state += np.array([tbs, rv1, rv2, rv3])
    out["harq_rv0"], out["harq_rv1"], out["harq_rv2"], out["harq_rv3"] = (
        harq_state.tolist())
    return out


def kpm_window(int_dbm_trace: np.ndarray, load_ratio: float,
               rng: np.random.Generator, scenario: str = "cci") -> np.ndarray:
    """(T, 15) float array for a trace of interference powers."""
    harq = np.zeros(4)
    rows = []
    for x in int_dbm_trace:
        d = kpm_step(float(x), load_ratio, rng, harq, scenario)
        rows.append([d[k] for k in KPMS_15])
    return np.asarray(rows, np.float32)


def normalize_kpms(x: np.ndarray) -> np.ndarray:
    """Fixed affine normalisation (deployment can't peek at test stats)."""
    center = np.array([-85, -10.5, 22, -3, 2, 13, 1,
                       15, 7, 14, 0.5, 400, 40, 8, 2], np.float32)
    scale = np.array([5, 2, 5, 1, 1, 3, 1,
                      15, 7, 14, 0.5, 400, 60, 15, 6], np.float32)
    return (x - center) / scale
