"""KPM trace synthesis (gNB-side, 0.1 s reporting period, Near-RT RIC xApp).

Feature sets:
  KPMS_7   — Minovski et al. [8]: RSRP, RSRQ, SINR, P_a, RI, CQI, CRI
  KPMS_8   — the paper's additions: PUSCH-SINR, TPC, UL-MCS, UL-BLER,
             HARQ-RV0..3 counters
  KPMS_15  — both.

Key modelled effect (Fig. 2b): under LOW UL load the UE's few allocated PRBs
dodge the interference, so the 15 numerical KPMs stay nominal while the max
*achievable* throughput collapses — only the IQ spectrogram reveals it.
"""
from __future__ import annotations

import numpy as np

from repro.channel import throughput as tp

KPMS_7 = ["rsrp", "rsrq", "sinr", "p_a", "ri", "cqi", "cri"]
KPMS_8 = ["pusch_sinr", "tpc", "ul_mcs", "ul_bler",
          "harq_rv0", "harq_rv1", "harq_rv2", "harq_rv3"]
KPMS_15 = KPMS_7 + KPMS_8


# How much of the interference footprint overlaps the low PRBs that carry a
# small grant: barrage jamming hits them too; CCI blocks dodge them; TDD
# cross-link hits trailing symbols only.
SCENARIO_OVERLAP = {"none": 0.0, "jamming": 0.8, "cci": 0.35, "tdd": 0.6}


def scenario_overlap(scenario) -> np.ndarray:
    """SCENARIO_OVERLAP lookup for a scalar / array of scenario names."""
    scen = np.asarray(scenario)
    if scen.ndim == 0:
        return np.float64(SCENARIO_OVERLAP.get(str(scen), 0.3))
    flat = [SCENARIO_OVERLAP.get(str(s), 0.3) for s in scen.ravel()]
    return np.asarray(flat, float).reshape(scen.shape)


def kpm_window_batch(int_dbm: np.ndarray, load_ratio,
                     rng: np.random.Generator, scenario="cci") -> np.ndarray:
    """(N, T, 15) KPM reports for N UEs' interference traces in one shot.

    ``int_dbm``: (N, T) traces; ``load_ratio``: scalar or (N,);
    ``scenario``: one name, (N,) per-UE names, or an (N, T) per-step grid
    (mid-episode scenario handover changes the interference footprint that
    overlaps a small grant, hence the per-step form). HARQ RV counters
    accumulate along T like a per-trace running state: rv0 = new TBs,
    rv1 = first retx (rv0 * BLER), rv2/3 appear when BLER saturates (the
    paper's OOC-zone estimator signal).
    """
    x = np.asarray(int_dbm, float)
    assert x.ndim == 2, f"int_dbm must be (N, T), got {x.shape}"
    N, T = x.shape
    lr = np.broadcast_to(np.asarray(load_ratio, float), (N,))
    ov = np.asarray(scenario_overlap(scenario), float)
    ov = np.broadcast_to(ov[..., None] if ov.ndim == 1 else ov, (N, T))

    def n(s, shape=(N, T)):
        return rng.normal(0.0, s, shape)

    out = np.empty((N, T, len(KPMS_15)), np.float32)
    col = {k: i for i, k in enumerate(KPMS_15)}
    # DL-side metrics: unaffected by UL interference (paper's 7-KPM baseline
    # fails exactly because of this)
    out[:, :, col["rsrp"]] = -85.0 + n(1.0)
    out[:, :, col["rsrq"]] = -10.5 + n(0.5)
    out[:, :, col["sinr"]] = 22.0 + n(1.0)
    out[:, :, col["p_a"]] = -3.0 + n(0.2)
    out[:, :, col["ri"]] = 2.0 + (rng.random((N, T)) < 0.05)
    out[:, :, col["cqi"]] = 13.0 + np.round(n(0.6))
    out[:, :, col["cri"]] = 1.0
    # UL metrics see the interference hitting the *allocated* PRBs
    visible = np.maximum(np.clip((lr - 0.15) / 0.85, 0.0, 1.0)[:, None], ov)
    eff_int = x * visible + (-60.0) * (1 - visible)
    b = tp.bler(eff_int)
    out[:, :, col["pusch_sinr"]] = tp.sinr_db(eff_int) + n(0.8)
    out[:, :, col["tpc"]] = tp.tpc_boost_db(eff_int) + n(0.3)
    out[:, :, col["ul_mcs"]] = tp.mcs_index(eff_int)
    out[:, :, col["ul_bler"]] = np.clip(b + n(0.02), 0, 1)
    # HARQ RV chains: per-step new TBs and retx draws, then a cumulative
    # sum along T reproduces the sequential harq_state accumulator
    bp = np.minimum(b, 1.0)
    tbs = rng.poisson(80 * lr[:, None] + 1, (N, T))
    rv1 = rng.binomial(tbs, bp)
    rv2 = rng.binomial(rv1, bp)
    rv3 = rng.binomial(rv2, bp)
    for k, inc in (("harq_rv0", tbs), ("harq_rv1", rv1),
                   ("harq_rv2", rv2), ("harq_rv3", rv3)):
        out[:, :, col[k]] = np.cumsum(inc, axis=1)
    return out


def kpm_window(int_dbm_trace: np.ndarray, load_ratio: float,
               rng: np.random.Generator, scenario: str = "cci") -> np.ndarray:
    """(T, 15) float array for a trace of interference powers (shim over
    the batched path)."""
    return kpm_window_batch(np.asarray(int_dbm_trace, float)[None],
                            load_ratio, rng, scenario)[0]


# The fixed affine normalisation (deployment can't peek at test stats).
# Module-level so the fused featurize kernel (repro.kernels.featurize) and
# this host path share one definition — drift here would silently break
# the fused-vs-unfused allclose pins.
KPM_CENTER = np.array([-85, -10.5, 22, -3, 2, 13, 1,
                       15, 7, 14, 0.5, 400, 40, 8, 2], np.float32)
KPM_SCALE = np.array([5, 2, 5, 1, 1, 3, 1,
                      15, 7, 14, 0.5, 400, 60, 15, 6], np.float32)


def normalize_kpms(x: np.ndarray) -> np.ndarray:
    """Fixed affine normalisation (deployment can't peek at test stats)."""
    return (x - KPM_CENTER) / KPM_SCALE
