"""Interference scenarios (Fig. 4) + estimator dataset generation.

S0 none | S1 jamming (signal generator) | S2 UE-to-BS CCI | S3 BS-to-BS TDD
pattern mismatch. Each episode draws an interference-power trajectory,
produces 0.1s KPM reports, per-window IQ spectrograms, and the ground-truth
max achievable throughput label.

The production path is batched: ``gen_episode_batch`` emits (N, T, ...)
arrays for N UEs in one shot (the substrate ``repro.sim`` fleets run on);
``gen_episode``/``gen_dataset`` are thin shims over it that keep the
original per-sample API.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.channel import iq as iqmod
from repro.channel import kpm as kpmmod
from repro.channel import throughput as tpmod

SCENARIOS = ("none", "jamming", "cci", "tdd")
WINDOW = 30  # paper: LSTM window=30 KPM reports


@dataclasses.dataclass
class Sample:
    kpms: np.ndarray  # (WINDOW, 15)
    iq: np.ndarray  # (2, n_sc, 14)
    alloc_ratio: float
    tp_mbps: float
    scenario: str
    int_dbm: float


def power_sum_dbm(base_dbm: np.ndarray, extra_mw: np.ndarray) -> np.ndarray:
    """Power-sum an extra interference term (mW) onto a dBm trace.

    Used for the load-dependent inter-cell floor: the scenario's own
    interference and the neighbour-cell contribution add in linear power.
    Clipped to the model's 14 dBm ceiling (deep OOC) like the base traces.
    """
    p_mw = 10 ** (np.asarray(base_dbm, float) / 10) + np.asarray(
        extra_mw, float)
    return np.minimum(10 * np.log10(np.maximum(p_mw, 1e-12)), 14.0)


def interference_trace_batch(scenarios, T: int, rng: np.random.Generator,
                             extra_mw: np.ndarray | None = None
                             ) -> np.ndarray:
    """(N, T) interference power (dBm): one trace per requested scenario.

    ``extra_mw``: optional (N, T) load-dependent floor (linear mW) power-
    summed onto every trace — e.g. the neighbour-cell contribution
    ``coupling @ cell_load`` from ``repro.sim.cells``. It raises even the
    "none" rows: an S0 UE in a loaded neighbourhood is no longer quiet.
    """
    scen = np.asarray(scenarios)
    N = len(scen)
    base = rng.uniform(-30, 10, N)
    walk = np.cumsum(rng.normal(0, 1.0, (N, T)), axis=1)
    tr = base[:, None] + walk - walk.mean(axis=1, keepdims=True)
    # bursty on/off jammer
    on = np.sin(np.arange(T)[None] / rng.uniform(3, 10, N)[:, None]) > -0.3
    tr = np.where((scen == "jamming")[:, None] & ~on, -60.0, tr)
    tr = np.where((scen == "none")[:, None], -60.0, np.clip(tr, -60, 14))
    return tr if extra_mw is None else power_sum_dbm(tr, extra_mw)


def interference_trace(scenario: str, T: int,
                       rng: np.random.Generator) -> np.ndarray:
    """(T,) trace for one scenario (shim over the batched path)."""
    return interference_trace_batch([scenario], T, rng)[0]


@dataclasses.dataclass
class EpisodeBatch:
    """N parallel episodes as stacked arrays (the fleet engine's input).

    ``int_dbm``/``kpms`` cover the full ``T + WINDOW`` trace (the warm-up
    prefix fills the first estimator window); labels and spectrograms exist
    for the T reporting steps. ``scenario_idx`` indexes ``SCENARIOS``.
    """

    scenario_idx: np.ndarray  # (N,) int
    alloc_ratio: np.ndarray  # (N,)
    int_dbm: np.ndarray  # (N, T + WINDOW)
    kpms: np.ndarray | None  # (N, T + WINDOW, 15) raw reports, or None
    tp_mbps: np.ndarray  # (N, T) ground-truth labels
    iq: np.ndarray | None  # (N, T, 2, n_sc, 14) or None if not requested

    @property
    def n_ues(self) -> int:
        return self.int_dbm.shape[0]

    @property
    def n_steps(self) -> int:
        return self.tp_mbps.shape[1]

    def kpm_windows(self, normalize: bool = True,
                    method: str = "view") -> np.ndarray:
        """(N, T, WINDOW, 15) rolling estimator windows: step t sees the
        WINDOW reports strictly before trace position ``WINDOW + t``.

        ``method="view"`` (default) is the zero-copy stride-trick form:
        a non-contiguous, non-writable view whose window axis aliases the
        trace axis — cheap, but it pins the trace's buffer layout (a
        consumer that assumes C-contiguity, writes in place, or hands the
        strides to foreign code gets silent corruption).
        ``method="gather"`` is the contiguity-safe fancy-index form: a
        fresh C-contiguous, writable array, WINDOW x the memory. The two
        are bit-equal element-for-element
        (``tests/test_channel_shims.py``); pick by what downstream does
        with the buffer, not by the numbers."""
        if self.kpms is None:
            raise ValueError("episode was generated with include_kpms=False")
        k = kpmmod.normalize_kpms(self.kpms) if normalize else self.kpms
        if method == "view":
            win = np.lib.stride_tricks.sliding_window_view(k, WINDOW, axis=1)
            return win.transpose(0, 1, 3, 2)[:, :self.n_steps]
        if method == "gather":
            t_idx = (np.arange(self.n_steps)[:, None]
                     + np.arange(WINDOW)[None, :])  # (T, WINDOW)
            return np.ascontiguousarray(k[:, t_idx])
        raise ValueError(f"method must be 'view' or 'gather': {method!r}")


def gen_episode_batch(scenarios, T: int, rng: np.random.Generator,
                      load_ratio=None, n_sc: int = iqmod.N_SC,
                      include_iq: bool = True, include_kpms: bool = True,
                      int_dbm: np.ndarray | None = None,
                      extra_int_mw: np.ndarray | None = None) -> EpisodeBatch:
    """Generate N episodes in one vectorized pass.

    Returns an ``EpisodeBatch`` of stacked arrays — the fleet engine's
    input: ``int_dbm`` (N, T + WINDOW) interference traces in dBm,
    ``kpms`` (N, T + WINDOW, 15) raw KPM reports, ``tp_mbps`` (N, T)
    ground-truth throughput labels in Mbps, and (when ``include_iq``)
    ``iq`` (N, T, 2, n_sc, 14) spectrograms. The first WINDOW trace steps
    are warm-up that fills the estimator's first KPM window; the T
    remaining steps are the 0.1 s report periods.

    ``scenarios``: (N,) scenario names, or an (N, T + WINDOW) name grid for
    mid-episode scenario handover. ``load_ratio``: None (drawn per UE),
    scalar, or (N,) — the UE's UL PRB allocation ratio in [0, 1].
    ``int_dbm`` overrides the drawn interference traces
    (shape (N, T + WINDOW), dBm — e.g. fixed operating points around a
    mean). ``extra_int_mw``: optional (N, T + WINDOW) load-dependent
    interference floor (linear mW, e.g. neighbour-cell load x coupling
    from ``repro.sim.cells``) power-summed onto the traces before KPMs,
    IQ and labels are derived, so every downstream signal sees the
    coupling. ``include_kpms=False`` skips KPM-report synthesis
    (``kpms`` is None) for callers that only need interference traces
    and throughput labels — e.g. the slot-pool churn benchmark, where
    tens of thousands of short sessions would otherwise materialize
    gigabytes of unused reports.
    """
    scen = np.asarray(scenarios)
    scen_grid = scen if scen.ndim == 2 else None
    scen0 = scen[:, 0] if scen.ndim == 2 else scen  # for trace + labels
    N = len(scen0)
    lr = (rng.uniform(0.05, 1.0, N) if load_ratio is None
          else np.broadcast_to(np.asarray(load_ratio, float), (N,)).copy())
    if int_dbm is None:
        if scen_grid is None:
            tr = interference_trace_batch(scen0, T + WINDOW, rng,
                                          extra_mw=extra_int_mw)
            extra_int_mw = None  # already folded in
        else:  # handover: every cell reads its row's trace for its scenario
            tr = np.empty((N, T + WINDOW))
            for s in np.unique(scen_grid):
                mask = scen_grid == s
                seg = interference_trace_batch(np.full(N, s), T + WINDOW, rng)
                tr[mask] = seg[mask]
    else:
        tr = np.asarray(int_dbm, float)
        assert tr.shape == (N, T + WINDOW), tr.shape
    if extra_int_mw is not None:
        tr = power_sum_dbm(tr, extra_int_mw)
    kpms = None
    if include_kpms:
        kpms = kpmmod.kpm_window_batch(tr, lr, rng,
                                       scen_grid if scen_grid is not None
                                       else scen0)
    tp = tpmod.max_throughput_mbps(tr[:, WINDOW:])
    iq = None
    if include_iq:
        rep = (scen_grid[:, WINDOW:] if scen_grid is not None
               else np.repeat(scen0, T).reshape(N, T))
        iq = iqmod.spectrogram_batch(
            tr[:, WINDOW:].ravel(), rep.ravel(), np.repeat(lr, T), rng,
            n_sc=n_sc).reshape(N, T, 2, n_sc, iqmod.N_SYM)
    sidx = np.array([SCENARIOS.index(s) if s in SCENARIOS else -1
                     for s in scen0])
    return EpisodeBatch(scenario_idx=sidx, alloc_ratio=lr, int_dbm=tr,
                        kpms=kpms, tp_mbps=tp, iq=iq)


def gen_episode(scenario: str, T: int, rng: np.random.Generator,
                load_ratio: float | None = None, n_sc: int = iqmod.N_SC
                ) -> list[Sample]:
    """Original per-sample episode API (shim over the batched path)."""
    ep = gen_episode_batch([scenario], T, rng, load_ratio=load_ratio,
                           n_sc=n_sc)
    windows = ep.kpm_windows(normalize=False)[0]  # (T, WINDOW, 15)
    return [Sample(kpms=windows[t], iq=ep.iq[0, t],
                   alloc_ratio=float(ep.alloc_ratio[0]),
                   tp_mbps=float(ep.tp_mbps[0, t]), scenario=scenario,
                   int_dbm=float(ep.int_dbm[0, WINDOW + t]))
            for t in range(T)]


def gen_dataset(n_per_scenario: int, rng: np.random.Generator,
                scenarios=SCENARIOS, episode_len: int = 20,
                low_load_only: bool = False, n_sc: int = iqmod.N_SC):
    """Arrays ready for the estimator: dict of stacked fields.

    One batched pass: enough whole episodes per scenario to reach
    ``n_per_scenario`` samples each (episodes are never truncated, so
    scenarios may exceed the target — same contract as the old loop).
    """
    n_eps = math.ceil(n_per_scenario / episode_len)
    scen = np.repeat(np.asarray(scenarios), n_eps)
    lr = rng.uniform(0.05, 0.2, len(scen)) if low_load_only else None
    ep = gen_episode_batch(scen, episode_len, rng, load_ratio=lr, n_sc=n_sc)
    n = ep.n_ues * ep.n_steps
    perm = rng.permutation(n)
    kpms = ep.kpm_windows(normalize=True).reshape(n, WINDOW, -1)[perm]
    return {"kpms": kpms.astype(np.float32),
            "iq": ep.iq.reshape((n,) + ep.iq.shape[2:])[perm]
            .astype(np.float32),
            "alloc": np.repeat(ep.alloc_ratio, ep.n_steps)[perm]
            .astype(np.float32),
            "tp": ep.tp_mbps.reshape(n)[perm].astype(np.float32),
            "scenario": np.repeat(ep.scenario_idx, ep.n_steps)[perm]}


# --------------------------------------------------------------------------
# Continuous UE arrival/departure (slot-pool churn)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Knobs for the continuous UE arrival/departure process.

    Arrivals are Poisson per report period with an optional diurnal
    (sinusoidal) modulation of the rate; session lengths are geometric
    with mean ``mean_dwell`` periods, capped at ``max_dwell`` (which also
    bounds the per-session trace length the engine must generate).
    ``max_admits`` is the number of fixed admission lanes per period in
    the jitted step — the admission *bandwidth*; arrivals beyond it (or
    beyond free capacity) queue in the global FIFO and show up as
    admission latency. Zero means "derive from the realised process".
    """

    arrival_rate: float = 8.0  # mean UE arrivals per report period
    diurnal_amplitude: float = 0.0  # 0 = homogeneous Poisson, (0, 1] = tide
    diurnal_period: int = 0  # periods per load cycle (0 -> one per horizon)
    mean_dwell: float = 20.0  # mean session length in report periods
    max_dwell: int = 0  # trace-length cap L (0 -> ceil(3 * mean_dwell))
    max_admits: int = 0  # admission lanes A per period (0 -> auto)

    def __post_init__(self):
        if self.arrival_rate < 0:
            raise ValueError(f"arrival_rate must be >= 0: {self.arrival_rate}")
        if not 0.0 <= self.diurnal_amplitude <= 1.0:
            raise ValueError(
                f"diurnal_amplitude must be in [0, 1]: {self.diurnal_amplitude}")
        if self.mean_dwell < 1.0:
            raise ValueError(f"mean_dwell must be >= 1: {self.mean_dwell}")


@dataclasses.dataclass
class ChurnSchedule:
    """A realised arrival process: the slot pool's global admission FIFO.

    Sessions are sorted by arrival period; the engine admits them in
    order as capacity frees up. ``ready_end[t]`` counts sessions with
    ``arrival_t <= t`` — the FIFO prefix eligible for admission at
    period t (precomputed host-side so the jitted step only compares
    its running next-arrival pointer against a scalar).
    """

    arrival_t: np.ndarray  # (M,) int32, sorted arrival period per session
    dwell: np.ndarray  # (M,) int32 session length in periods, >= 1
    ready_end: np.ndarray  # (T,) int32 cumulative arrivals through period t
    horizon: int  # T report periods
    max_admits: int  # A admission lanes per period

    @property
    def n_sessions(self) -> int:
        return int(self.arrival_t.shape[0])

    @property
    def max_dwell(self) -> int:
        return int(self.dwell.max()) if self.dwell.size else 1


def diurnal_arrival_rate(cfg: ChurnConfig, T: int) -> np.ndarray:
    """(T,) per-period Poisson arrival rate with diurnal modulation."""
    lam = np.full(T, float(cfg.arrival_rate))
    if cfg.diurnal_amplitude > 0.0:
        period = cfg.diurnal_period if cfg.diurnal_period > 0 else T
        phase = 2.0 * np.pi * np.arange(T) / max(period, 1)
        lam = lam * (1.0 + cfg.diurnal_amplitude * np.sin(phase))
    return np.maximum(lam, 0.0)


def make_churn_schedule(cfg: ChurnConfig, T: int,
                        rng: np.random.Generator) -> ChurnSchedule:
    """Draw a concrete arrival/departure realisation over T periods.

    The auto ``max_admits`` is twice the busiest period's arrivals
    (at least 1): wide enough that a drained pool catches up on a
    backlog within a few periods, narrow enough to keep the fixed
    admission lanes cheap.
    """
    lam = diurnal_arrival_rate(cfg, T)
    counts = rng.poisson(lam).astype(np.int64)
    arrival_t = np.repeat(np.arange(T, dtype=np.int32),
                          counts).astype(np.int32)
    m = int(arrival_t.shape[0])
    max_dwell = cfg.max_dwell if cfg.max_dwell > 0 else int(
        math.ceil(3.0 * cfg.mean_dwell))
    max_dwell = max(max_dwell, 1)
    if m:
        dwell = rng.geometric(1.0 / float(cfg.mean_dwell), m)
        dwell = np.clip(dwell, 1, max_dwell).astype(np.int32)
    else:
        dwell = np.zeros(0, np.int32)
    ready_end = np.cumsum(counts).astype(np.int32)
    max_admits = cfg.max_admits if cfg.max_admits > 0 else max(
        1, 2 * int(counts.max(initial=0)))
    return ChurnSchedule(arrival_t=arrival_t, dwell=dwell,
                         ready_end=ready_end, horizon=int(T),
                         max_admits=int(max_admits))
