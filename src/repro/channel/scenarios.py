"""Interference scenarios (Fig. 4) + estimator dataset generation.

S0 none | S1 jamming (signal generator) | S2 UE-to-BS CCI | S3 BS-to-BS TDD
pattern mismatch. Each episode draws an interference-power trajectory,
produces 0.1s KPM reports, per-window IQ spectrograms, and the ground-truth
max achievable throughput label.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.channel import iq as iqmod
from repro.channel import kpm as kpmmod
from repro.channel import throughput as tpmod

SCENARIOS = ("none", "jamming", "cci", "tdd")
WINDOW = 30  # paper: LSTM window=30 KPM reports


@dataclasses.dataclass
class Sample:
    kpms: np.ndarray  # (WINDOW, 15)
    iq: np.ndarray  # (2, n_sc, 14)
    alloc_ratio: float
    tp_mbps: float
    scenario: str
    int_dbm: float


def interference_trace(scenario: str, T: int,
                       rng: np.random.Generator) -> np.ndarray:
    """Interference power (dBm) over T reporting periods."""
    if scenario == "none":
        return np.full(T, -60.0)
    base = rng.uniform(-30, 10)
    walk = np.cumsum(rng.normal(0, 1.0, T))
    tr = base + walk - walk.mean()
    if scenario == "jamming":  # bursty on/off jammer
        on = (np.sin(np.arange(T) / rng.uniform(3, 10)) > -0.3)
        tr = np.where(on, tr, -60.0)
    return np.clip(tr, -60, 14)


def gen_episode(scenario: str, T: int, rng: np.random.Generator,
                load_ratio: float | None = None, n_sc: int = iqmod.N_SC
                ) -> list[Sample]:
    lr = rng.uniform(0.05, 1.0) if load_ratio is None else load_ratio
    tr = interference_trace(scenario, T + WINDOW, rng)
    kpms = kpmmod.kpm_window(tr, lr, rng, scenario)
    out = []
    for t in range(WINDOW, T + WINDOW):
        x = float(tr[t])
        out.append(Sample(
            kpms=kpms[t - WINDOW:t],
            iq=iqmod.spectrogram(x, scenario, lr, rng, n_sc=n_sc),
            alloc_ratio=lr,
            tp_mbps=float(tpmod.max_throughput_mbps(np.array(x))),
            scenario=scenario,
            int_dbm=x,
        ))
    return out


def gen_dataset(n_per_scenario: int, rng: np.random.Generator,
                scenarios=SCENARIOS, episode_len: int = 20,
                low_load_only: bool = False, n_sc: int = iqmod.N_SC):
    """Arrays ready for the estimator: dict of stacked fields."""
    samples: list[Sample] = []
    while min(sum(s.scenario == sc for s in samples) for sc in scenarios
              ) < n_per_scenario if samples else True:
        for sc in scenarios:
            lr = rng.uniform(0.05, 0.2) if low_load_only else None
            samples.extend(gen_episode(sc, episode_len, rng, load_ratio=lr,
                                       n_sc=n_sc))
        if all(sum(s.scenario == sc for s in samples) >= n_per_scenario
               for sc in scenarios):
            break
    rng.shuffle(samples)
    kpms = np.stack([kpmmod.normalize_kpms(s.kpms) for s in samples])
    iqs = np.stack([s.iq for s in samples])
    alloc = np.array([s.alloc_ratio for s in samples], np.float32)
    y = np.array([s.tp_mbps for s in samples], np.float32)
    meta = np.array([SCENARIOS.index(s.scenario) for s in samples])
    return {"kpms": kpms.astype(np.float32), "iq": iqs.astype(np.float32),
            "alloc": alloc, "tp": y, "scenario": meta}
