"""Interference scenarios (Fig. 4) + estimator dataset generation.

S0 none | S1 jamming (signal generator) | S2 UE-to-BS CCI | S3 BS-to-BS TDD
pattern mismatch. Each episode draws an interference-power trajectory,
produces 0.1s KPM reports, per-window IQ spectrograms, and the ground-truth
max achievable throughput label.

The production path is batched: ``gen_episode_batch`` emits (N, T, ...)
arrays for N UEs in one shot (the substrate ``repro.sim`` fleets run on);
``gen_episode``/``gen_dataset`` are thin shims over it that keep the
original per-sample API.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.channel import iq as iqmod
from repro.channel import kpm as kpmmod
from repro.channel import throughput as tpmod

SCENARIOS = ("none", "jamming", "cci", "tdd")
WINDOW = 30  # paper: LSTM window=30 KPM reports


@dataclasses.dataclass
class Sample:
    kpms: np.ndarray  # (WINDOW, 15)
    iq: np.ndarray  # (2, n_sc, 14)
    alloc_ratio: float
    tp_mbps: float
    scenario: str
    int_dbm: float


def power_sum_dbm(base_dbm: np.ndarray, extra_mw: np.ndarray) -> np.ndarray:
    """Power-sum an extra interference term (mW) onto a dBm trace.

    Used for the load-dependent inter-cell floor: the scenario's own
    interference and the neighbour-cell contribution add in linear power.
    Clipped to the model's 14 dBm ceiling (deep OOC) like the base traces.
    """
    p_mw = 10 ** (np.asarray(base_dbm, float) / 10) + np.asarray(
        extra_mw, float)
    return np.minimum(10 * np.log10(np.maximum(p_mw, 1e-12)), 14.0)


def interference_trace_batch(scenarios, T: int, rng: np.random.Generator,
                             extra_mw: np.ndarray | None = None
                             ) -> np.ndarray:
    """(N, T) interference power (dBm): one trace per requested scenario.

    ``extra_mw``: optional (N, T) load-dependent floor (linear mW) power-
    summed onto every trace — e.g. the neighbour-cell contribution
    ``coupling @ cell_load`` from ``repro.sim.cells``. It raises even the
    "none" rows: an S0 UE in a loaded neighbourhood is no longer quiet.
    """
    scen = np.asarray(scenarios)
    N = len(scen)
    base = rng.uniform(-30, 10, N)
    walk = np.cumsum(rng.normal(0, 1.0, (N, T)), axis=1)
    tr = base[:, None] + walk - walk.mean(axis=1, keepdims=True)
    # bursty on/off jammer
    on = np.sin(np.arange(T)[None] / rng.uniform(3, 10, N)[:, None]) > -0.3
    tr = np.where((scen == "jamming")[:, None] & ~on, -60.0, tr)
    tr = np.where((scen == "none")[:, None], -60.0, np.clip(tr, -60, 14))
    return tr if extra_mw is None else power_sum_dbm(tr, extra_mw)


def interference_trace(scenario: str, T: int,
                       rng: np.random.Generator) -> np.ndarray:
    """(T,) trace for one scenario (shim over the batched path)."""
    return interference_trace_batch([scenario], T, rng)[0]


@dataclasses.dataclass
class EpisodeBatch:
    """N parallel episodes as stacked arrays (the fleet engine's input).

    ``int_dbm``/``kpms`` cover the full ``T + WINDOW`` trace (the warm-up
    prefix fills the first estimator window); labels and spectrograms exist
    for the T reporting steps. ``scenario_idx`` indexes ``SCENARIOS``.
    """

    scenario_idx: np.ndarray  # (N,) int
    alloc_ratio: np.ndarray  # (N,)
    int_dbm: np.ndarray  # (N, T + WINDOW)
    kpms: np.ndarray  # (N, T + WINDOW, 15) raw (unnormalized) reports
    tp_mbps: np.ndarray  # (N, T) ground-truth labels
    iq: np.ndarray | None  # (N, T, 2, n_sc, 14) or None if not requested

    @property
    def n_ues(self) -> int:
        return self.int_dbm.shape[0]

    @property
    def n_steps(self) -> int:
        return self.tp_mbps.shape[1]

    def kpm_windows(self, normalize: bool = True) -> np.ndarray:
        """(N, T, WINDOW, 15) rolling estimator windows: step t sees the
        WINDOW reports strictly before trace position ``WINDOW + t``."""
        k = kpmmod.normalize_kpms(self.kpms) if normalize else self.kpms
        win = np.lib.stride_tricks.sliding_window_view(k, WINDOW, axis=1)
        return win.transpose(0, 1, 3, 2)[:, :self.n_steps]


def gen_episode_batch(scenarios, T: int, rng: np.random.Generator,
                      load_ratio=None, n_sc: int = iqmod.N_SC,
                      include_iq: bool = True,
                      int_dbm: np.ndarray | None = None,
                      extra_int_mw: np.ndarray | None = None) -> EpisodeBatch:
    """Generate N episodes in one vectorized pass.

    Returns an ``EpisodeBatch`` of stacked arrays — the fleet engine's
    input: ``int_dbm`` (N, T + WINDOW) interference traces in dBm,
    ``kpms`` (N, T + WINDOW, 15) raw KPM reports, ``tp_mbps`` (N, T)
    ground-truth throughput labels in Mbps, and (when ``include_iq``)
    ``iq`` (N, T, 2, n_sc, 14) spectrograms. The first WINDOW trace steps
    are warm-up that fills the estimator's first KPM window; the T
    remaining steps are the 0.1 s report periods.

    ``scenarios``: (N,) scenario names, or an (N, T + WINDOW) name grid for
    mid-episode scenario handover. ``load_ratio``: None (drawn per UE),
    scalar, or (N,) — the UE's UL PRB allocation ratio in [0, 1].
    ``int_dbm`` overrides the drawn interference traces
    (shape (N, T + WINDOW), dBm — e.g. fixed operating points around a
    mean). ``extra_int_mw``: optional (N, T + WINDOW) load-dependent
    interference floor (linear mW, e.g. neighbour-cell load x coupling
    from ``repro.sim.cells``) power-summed onto the traces before KPMs,
    IQ and labels are derived, so every downstream signal sees the
    coupling.
    """
    scen = np.asarray(scenarios)
    scen_grid = scen if scen.ndim == 2 else None
    scen0 = scen[:, 0] if scen.ndim == 2 else scen  # for trace + labels
    N = len(scen0)
    lr = (rng.uniform(0.05, 1.0, N) if load_ratio is None
          else np.broadcast_to(np.asarray(load_ratio, float), (N,)).copy())
    if int_dbm is None:
        if scen_grid is None:
            tr = interference_trace_batch(scen0, T + WINDOW, rng,
                                          extra_mw=extra_int_mw)
            extra_int_mw = None  # already folded in
        else:  # handover: every cell reads its row's trace for its scenario
            tr = np.empty((N, T + WINDOW))
            for s in np.unique(scen_grid):
                mask = scen_grid == s
                seg = interference_trace_batch(np.full(N, s), T + WINDOW, rng)
                tr[mask] = seg[mask]
    else:
        tr = np.asarray(int_dbm, float)
        assert tr.shape == (N, T + WINDOW), tr.shape
    if extra_int_mw is not None:
        tr = power_sum_dbm(tr, extra_int_mw)
    kpms = kpmmod.kpm_window_batch(tr, lr, rng,
                                   scen_grid if scen_grid is not None
                                   else scen0)
    tp = tpmod.max_throughput_mbps(tr[:, WINDOW:])
    iq = None
    if include_iq:
        rep = (scen_grid[:, WINDOW:] if scen_grid is not None
               else np.repeat(scen0, T).reshape(N, T))
        iq = iqmod.spectrogram_batch(
            tr[:, WINDOW:].ravel(), rep.ravel(), np.repeat(lr, T), rng,
            n_sc=n_sc).reshape(N, T, 2, n_sc, iqmod.N_SYM)
    sidx = np.array([SCENARIOS.index(s) if s in SCENARIOS else -1
                     for s in scen0])
    return EpisodeBatch(scenario_idx=sidx, alloc_ratio=lr, int_dbm=tr,
                        kpms=kpms, tp_mbps=tp, iq=iq)


def gen_episode(scenario: str, T: int, rng: np.random.Generator,
                load_ratio: float | None = None, n_sc: int = iqmod.N_SC
                ) -> list[Sample]:
    """Original per-sample episode API (shim over the batched path)."""
    ep = gen_episode_batch([scenario], T, rng, load_ratio=load_ratio,
                           n_sc=n_sc)
    windows = ep.kpm_windows(normalize=False)[0]  # (T, WINDOW, 15)
    return [Sample(kpms=windows[t], iq=ep.iq[0, t],
                   alloc_ratio=float(ep.alloc_ratio[0]),
                   tp_mbps=float(ep.tp_mbps[0, t]), scenario=scenario,
                   int_dbm=float(ep.int_dbm[0, WINDOW + t]))
            for t in range(T)]


def gen_dataset(n_per_scenario: int, rng: np.random.Generator,
                scenarios=SCENARIOS, episode_len: int = 20,
                low_load_only: bool = False, n_sc: int = iqmod.N_SC):
    """Arrays ready for the estimator: dict of stacked fields.

    One batched pass: enough whole episodes per scenario to reach
    ``n_per_scenario`` samples each (episodes are never truncated, so
    scenarios may exceed the target — same contract as the old loop).
    """
    n_eps = math.ceil(n_per_scenario / episode_len)
    scen = np.repeat(np.asarray(scenarios), n_eps)
    lr = rng.uniform(0.05, 0.2, len(scen)) if low_load_only else None
    ep = gen_episode_batch(scen, episode_len, rng, load_ratio=lr, n_sc=n_sc)
    n = ep.n_ues * ep.n_steps
    perm = rng.permutation(n)
    kpms = ep.kpm_windows(normalize=True).reshape(n, WINDOW, -1)[perm]
    return {"kpms": kpms.astype(np.float32),
            "iq": ep.iq.reshape((n,) + ep.iq.shape[2:])[perm]
            .astype(np.float32),
            "alloc": np.repeat(ep.alloc_ratio, ep.n_steps)[perm]
            .astype(np.float32),
            "tp": ep.tp_mbps.reshape(n)[perm].astype(np.float32),
            "scenario": np.repeat(ep.scenario_idx, ep.n_steps)[perm]}
