"""Ground-truth maximum achievable UL throughput vs interference power.

Shannon-style per-PRB capacity with the gNB control loops of Fig. 2a:
  Negligible zone   : peak throughput (SINR >> target)
  Power-Control zone: TPC raises UE tx power, SINR held at target -> peak
                      (high load); for LOW load the un-allocated PRBs are
                      already degraded, so the *max achievable* rate drops
  MCS-Control zone  : power headroom exhausted; MCS steps down
  OOC zone          : BLER -> 100%, only HARQ retransmissions survive
"""
from __future__ import annotations

import numpy as np

# zone boundaries in interference power dBm (at the gNB receiver)
NEGLIGIBLE_MAX = -20.0
POWER_CTRL_MAX = -5.0
MCS_CTRL_MAX = 8.0

PEAK_MBPS = 130.0
SIG_DBM_BASE = -10.0  # received signal power without TPC boost
TPC_MAX_DB = 15.0  # power-control headroom
NOISE_FLOOR_DBM = -35.0


def tpc_boost_db(int_dbm: np.ndarray) -> np.ndarray:
    """gNB-commanded UE power boost (consumed in the Power-Control zone)."""
    x = (np.asarray(int_dbm, float) - NEGLIGIBLE_MAX) / (
        POWER_CTRL_MAX - NEGLIGIBLE_MAX)
    return TPC_MAX_DB * np.clip(x, 0.0, 1.0)


def sinr_db(int_dbm: np.ndarray, *, with_tpc: bool = True) -> np.ndarray:
    int_dbm = np.asarray(int_dbm, float)
    sig = SIG_DBM_BASE + (tpc_boost_db(int_dbm) if with_tpc else 0.0)
    noise_mw = 10 ** (NOISE_FLOOR_DBM / 10) + 10 ** (int_dbm / 10)
    return sig - 10 * np.log10(noise_mw)


def max_throughput_mbps(int_dbm: np.ndarray) -> np.ndarray:
    """Max achievable UL rate if the UE used the full grant."""
    s = sinr_db(int_dbm)
    snr = 10 ** (s / 10)
    cap = np.log2(1 + snr)
    peak_cap = np.log2(1 + 10 ** (sinr_db(np.array(-60.0)) / 10))
    tp = PEAK_MBPS * np.minimum(cap / peak_cap, 1.0)
    # OOC collapse: BLER saturates, effective goodput crumbles
    ooc = np.clip((np.asarray(int_dbm, float) - MCS_CTRL_MAX) / 4.0, 0, 1)
    return np.maximum(tp * (1 - 0.97 * ooc), 0.5)


PRB_FLOOR_MBPS = 0.01  # scheduling crumbs: even a starved UE sees a trickle


def prb_scaled_mbps(tp_mbps: np.ndarray, prb_share,
                    floor_mbps: float = PRB_FLOOR_MBPS) -> np.ndarray:
    """Throughput on a fractional PRB grant (fluid gNB scheduler model).

    ``tp_mbps`` is the full-grant max achievable rate; capacity scales
    linearly with the granted share of the cell's PRBs. Floored so a
    starved UE (max-C/I losers get share 0) keeps a finite E2E delay."""
    share = np.clip(np.asarray(prb_share, float), 0.0, 1.0)
    return np.maximum(np.asarray(tp_mbps, float) * share, floor_mbps)


def shared_throughput_mbps(int_dbm: np.ndarray, prb_share,
                           floor_mbps: float = PRB_FLOOR_MBPS) -> np.ndarray:
    """Max achievable UL rate on a fractional PRB grant."""
    return prb_scaled_mbps(max_throughput_mbps(int_dbm), prb_share,
                           floor_mbps)


def bler(int_dbm: np.ndarray) -> np.ndarray:
    """UL block error rate: ~10% target until OOC, then -> 1.0."""
    x = np.clip((np.asarray(int_dbm, float) - MCS_CTRL_MAX) / 3.0, 0, 1)
    return 0.1 + 0.9 * x**2


def mcs_index(int_dbm: np.ndarray) -> np.ndarray:
    """UL MCS: 28 until the MCS-Control zone, stepping to 0 at its end."""
    x = np.clip((np.asarray(int_dbm, float) - POWER_CTRL_MAX) / (
        MCS_CTRL_MAX - POWER_CTRL_MAX), 0, 1)
    return np.round(28 * (1 - x)).astype(int)
