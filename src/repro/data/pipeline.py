"""Deterministic synthetic data pipeline with background prefetch.

Batches are a pure function of (seed, step) so a restarted/resharded job
resumes bit-identically — the property the fault-tolerance tests pin.
"""
from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np


def make_batch_fn(cfg, seq: int, global_batch: int, seed: int = 0):
    """Returns step -> batch dict (host numpy, ready for device_put)."""

    def batch_at(step: int) -> dict:
        rng = np.random.default_rng((seed << 20) ^ step)
        b = {}
        if cfg.frame_input_dim:
            b["frames"] = rng.normal(size=(global_batch, seq,
                                           cfg.frame_input_dim)).astype(
                np.float32)
        else:
            # zipfian-ish tokens: structure for the model to learn
            z = rng.zipf(1.3, size=(global_batch, seq + 1))
            toks = np.minimum(z, cfg.vocab - 1).astype(np.int32)
            b["tokens"] = toks[:, :-1]
            b["labels"] = toks[:, 1:]
        if cfg.frame_input_dim:
            b["labels"] = rng.integers(
                0, cfg.vocab, size=(global_batch, seq)).astype(np.int32)
        if cfg.vision_dim:
            b["vision"] = rng.normal(size=(
                global_batch, cfg.vision_tokens, cfg.vision_dim)).astype(
                np.float32)
        return b

    return batch_at


class SyntheticLMData:
    """Prefetching iterator: a daemon thread keeps `depth` batches ready,
    optionally device_put against a sharding tree."""

    def __init__(self, cfg, seq, global_batch, *, seed=0, start_step=0,
                 shardings=None, depth=2):
        self.batch_at = make_batch_fn(cfg, seq, global_batch, seed)
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _put_device(self, b):
        if self.shardings is None:
            return {k: jnp.asarray(v) for k, v in b.items()}
        return {k: jax.device_put(v, self.shardings[k]) for k, v in b.items()}

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self.batch_at(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        step, b = self._q.get()
        return step, self._put_device(b)

    def close(self):
        self._stop.set()
