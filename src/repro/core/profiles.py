"""Per-split-point profiles: the inputs Algorithm 1 consumes.

A SplitProfile holds, for each candidate split l in {1..L}:
  flops_head[l]   cumulative FLOPs executed on the UE (layers 1..l)
  flops_tail[l]   remaining FLOPs on the edge
  data_bytes[l]   size of the transmitted intermediate activation
  privacy[l]      dCor(input, activation_l)  (lower = better)

Profiles come from three sources:
  * analytic layer math (VGG16, benchmarks — deterministic),
  * measured dcor on real forward passes (reduced-width nets on CPU),
  * compiled cost_analysis of LM blocks (launch/roofline calibration).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.energy import DeviceProfile


@dataclasses.dataclass
class SplitProfile:
    name: str
    flops_head: np.ndarray  # (L,) cumulative
    data_bytes: np.ndarray  # (L,)
    privacy: np.ndarray  # (L,) in [0,1]
    layer_names: list[str]

    @property
    def n_splits(self) -> int:
        return len(self.flops_head)

    @property
    def total_flops(self) -> float:
        return float(self.flops_head[-1])

    def d_ue(self, ue: DeviceProfile) -> np.ndarray:
        return self.flops_head / ue.flops_per_s

    def d_ser(self, server: DeviceProfile) -> np.ndarray:
        rem = self.total_flops - self.flops_head
        return server.fixed_latency_s + rem / server.flops_per_s

    def d_trx(self, tp_bps: np.ndarray) -> np.ndarray:
        """(L, T) transmission latency for throughputs tp_bps (bits/s)."""
        return self.data_bytes[:, None] * 8.0 / np.asarray(tp_bps)[None, :]

    def e_ue(self, ue: DeviceProfile) -> np.ndarray:
        return ue.tdp_w / ue.threads * self.d_ue(ue)

    def scaled(self, codec_ratio: float) -> "SplitProfile":
        """Profile under a boundary codec that shrinks activations."""
        return dataclasses.replace(
            self, data_bytes=self.data_bytes * codec_ratio,
            name=f"{self.name}|codec x{codec_ratio:.3f}")


def lm_split_profile(cfg, seq: int, batch: int, *, bytes_per_el: int = 2,
                     privacy: np.ndarray | None = None) -> SplitProfile:
    """Analytic profile for an assigned LM architecture split at megablock
    boundaries. Activation size is constant in l (d_model residual stream) —
    the transformer-specific PSO regime discussed in DESIGN.md §4."""
    L = cfg.n_layers
    per_layer = []
    for i in range(L):
        b = cfg.pattern[i % len(cfg.pattern)]
        if b.kind in ("attn", "local", "cross"):
            attn = 2 * cfg.d_model * (cfg.n_heads + 2 * cfg.kv_heads) * (
                cfg.head_dim) + 2 * cfg.n_heads * cfg.head_dim * cfg.d_model
            ctx = min(seq, b.window) if b.window else seq
            attn += 4 * cfg.n_heads * cfg.head_dim * ctx  # qk^T + av
            ff_mult = cfg.top_k if cfg.is_moe else 1
            ff = 6 * cfg.d_model * cfg.d_ff * ff_mult
            per_layer.append((attn + ff) * 2 * seq * batch / 2)
        elif b.kind == "rec":
            w = cfg.lru_width
            per_layer.append((2 * cfg.d_model * w * 3 + 2 * w * w * 2 +
                              6 * cfg.d_model * cfg.d_ff) * seq * batch)
        elif b.kind == "ssd":
            nh = cfg.d_inner // cfg.ssm_headdim
            core = 2 * cfg.d_model * (2 * cfg.d_inner) + 2 * cfg.d_inner * (
                cfg.d_model)
            ssd = 4 * cfg.d_inner * cfg.ssm_state * min(seq, cfg.ssm_chunk)
            del nh
            per_layer.append((core + ssd) * seq * batch)
    flops_head = np.cumsum(per_layer)
    data = np.full(L, seq * batch * cfg.d_model * bytes_per_el, float)
    if privacy is None:
        # deep layers leak less; exponential-ish decay matching Fig. 5b shape
        privacy = 0.95 * np.exp(-2.2 * np.arange(1, L + 1) / L) + 0.20
    return SplitProfile(
        name=f"{cfg.name}-s{seq}b{batch}", flops_head=flops_head.astype(float),
        data_bytes=data, privacy=np.asarray(privacy, float),
        layer_names=[f"block{i+1}" for i in range(L)])
