"""Pre-Filtered Split Optimization (PSO) — Algorithm 1, verbatim + vectorised.

For each UE: (1) prefilter split points violating privacy/energy constraints,
(2) compute the minimal throughput TP_min(l) that keeps the latency constraint
satisfiable, (3) for every integer TP in {1..TP_max} pick
l* = argmin over feasible l of F(l, TP). The result is an O(1)-lookup table
the Application Function queries with the estimated throughput.

``pso_reference`` is a line-by-line transcription of the pseudocode (loops);
``pso_vectorized`` is the production path. A hypothesis property test pins
them equal.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.energy import DeviceProfile
from repro.core.objective import Constraints, Weights, evaluate
from repro.core.profiles import SplitProfile

NO_SPLIT = -1  # no feasible split at this throughput

# Clamp range for throughput estimates before they hit a lookup table:
# 1 Mbps is the first bucket the sweep fills (bucket 0 stays NO_SPLIT),
# 130 Mbps the paper's peak rate (channel.throughput.PEAK_MBPS) and the
# tp_max the production tables are built with. This is part of the sweep
# config — ``repro.sim`` imports it rather than re-declaring the range.
TP_CLIP_MBPS = (1.0, 130.0)


@dataclasses.dataclass
class LookupTable:
    """tp (Mbps, rounded int) -> optimal split index (0-based; NO_SPLIT)."""

    ue_name: str
    table: np.ndarray  # (tp_max+1,) int32; index tp in Mbps
    tp_min_mbps: np.ndarray  # (L,) minimal feasible throughput per split
    feasible_prefilter: np.ndarray  # (L,) bool after privacy/energy filter

    def query(self, tp_mbps: float) -> int:
        """Rounded-bucket lookup. Near-zero throughput rounds to bucket 0,
        which the sweep never fills (it starts at 1 Mbps) and therefore
        reads NO_SPLIT — clamping up to bucket 1 would return a split
        whose TP_min may be unmet at the actual throughput."""
        tp = int(np.clip(round(tp_mbps), 0, len(self.table) - 1))
        return int(self.table[tp])


@dataclasses.dataclass
class StackedLookupTable:
    """Many UE lookup tables stacked for fleet-scale vectorized queries.

    ``tables[u, tp]`` is UE ``u``'s optimal split at (rounded-int) ``tp``
    Mbps — the same layout as ``LookupTable.table`` with a leading UE axis,
    so it drops straight into ``jax.vmap``-ed ``controller_step`` rows.
    All stacked tables must share ``tp_max`` (and, for the per-split
    metadata, the same number of split points L).
    """

    ue_names: list[str]
    tables: np.ndarray  # (U, tp_max+1) int32
    tp_min_mbps: np.ndarray  # (U, L)
    feasible_prefilter: np.ndarray  # (U, L) bool

    @classmethod
    def stack(cls, tables: list[LookupTable]) -> "StackedLookupTable":
        assert tables, "need at least one table"
        widths = {len(t.table) for t in tables}
        assert len(widths) == 1, f"mixed tp_max across tables: {widths}"
        return cls(ue_names=[t.ue_name for t in tables],
                   tables=np.stack([t.table for t in tables]),
                   tp_min_mbps=np.stack([t.tp_min_mbps for t in tables]),
                   feasible_prefilter=np.stack(
                       [t.feasible_prefilter for t in tables]))

    @property
    def n_ues(self) -> int:
        return self.tables.shape[0]

    def row(self, u: int) -> LookupTable:
        return LookupTable(self.ue_names[u], self.tables[u],
                           self.tp_min_mbps[u], self.feasible_prefilter[u])

    def query_many(self, tp_mbps: np.ndarray,
                   ue_idx: np.ndarray | None = None) -> np.ndarray:
        """Vectorized ``LookupTable.query``: one gather for the whole fleet.

        ``tp_mbps``: (...,) throughput estimates; ``ue_idx``: matching table
        row per estimate (default ``arange`` — one estimate per stacked UE).
        Keeps the 0-bucket semantics: near-zero throughput rounds to bucket
        0, which the sweep never fills, and therefore reads NO_SPLIT."""
        tp = np.asarray(tp_mbps, float)
        if ue_idx is None:
            assert tp.shape == (self.n_ues,), (
                f"default ue_idx needs one estimate per UE, got {tp.shape}")
            ue_idx = np.arange(self.n_ues)
        buckets = np.clip(np.round(tp), 0,
                          self.tables.shape[1] - 1).astype(np.int64)
        return self.tables[np.asarray(ue_idx), buckets]


def _tp_min(profile: SplitProfile, ue: DeviceProfile, server: DeviceProfile,
            cons: Constraints) -> np.ndarray:
    """Line 5-6: minimal throughput (bps) that meets the latency budget."""
    slack = cons.tau_max_s - profile.d_ue(ue) - profile.d_ser(server)
    with np.errstate(divide="ignore"):
        tp = np.where(slack > 0, profile.data_bytes * 8.0 / np.maximum(
            slack, 1e-12), np.inf)
    return tp


def pso_reference(profile: SplitProfile, ue: DeviceProfile,
                  server: DeviceProfile, weights: Weights, cons: Constraints,
                  tp_max_mbps: int) -> LookupTable:
    """Direct pseudocode transcription of Algorithm 1 (single UE)."""
    L = profile.n_splits
    d_ue = profile.d_ue(ue)
    d_ser = profile.d_ser(server)
    e_ue = profile.e_ue(ue)
    p = profile.privacy
    # lines 2-7: prefilter + minimal throughput per split
    feas: list[tuple[int, float]] = []
    for l in range(L):
        if p[l] <= cons.rho_max and e_ue[l] <= cons.e_max_j:
            slack = cons.tau_max_s - d_ue[l] - d_ser[l]
            tp_min = (profile.data_bytes[l] * 8.0 / slack if slack > 0
                      else np.inf)
            feas.append((l, tp_min))
    # lines 8-13: sweep integer throughputs
    table = np.full(tp_max_mbps + 1, NO_SPLIT, np.int32)
    for tp in range(1, tp_max_mbps + 1):
        tp_bps = tp * 1e6
        cand = [l for (l, tpm) in feas if tpm <= tp_bps]
        if not cand:
            continue
        terms = evaluate(profile, ue, server, np.array([tp_bps]), weights,
                         cons)
        fvals = terms.f[cand, 0]
        best = int(np.argmin(fvals))
        if np.isfinite(fvals[best]):
            table[tp] = cand[best]
    tp_min_all = _tp_min(profile, ue, server, cons)
    pre = (p <= cons.rho_max) & (e_ue <= cons.e_max_j)
    return LookupTable(profile.name, table, tp_min_all / 1e6, pre)


def pso_vectorized(profile: SplitProfile, ue: DeviceProfile,
                   server: DeviceProfile, weights: Weights, cons: Constraints,
                   tp_max_mbps: int) -> LookupTable:
    """Vectorised Algorithm 1: one (L, T) objective evaluation."""
    tps = np.arange(1, tp_max_mbps + 1) * 1e6
    terms = evaluate(profile, ue, server, tps, weights, cons)
    pre = ((profile.privacy <= cons.rho_max)
           & (profile.e_ue(ue) <= cons.e_max_j))
    tp_min = _tp_min(profile, ue, server, cons)
    # a split is usable at tp if prefiltered AND tp >= TP_min(l)
    usable = pre[:, None] & (tp_min[:, None] <= tps[None, :]) & terms.feasible
    f = np.where(usable, terms.f, np.inf)
    best = np.argmin(f, axis=0)
    ok = np.isfinite(f[best, np.arange(len(tps))])
    table = np.full(tp_max_mbps + 1, NO_SPLIT, np.int32)
    table[1:] = np.where(ok, best, NO_SPLIT)
    return LookupTable(profile.name, table, tp_min / 1e6, pre)


def build_tables(profiles: dict[str, SplitProfile], ue: DeviceProfile,
                 server: DeviceProfile, weights: Weights, cons: Constraints,
                 tp_max_mbps: int) -> dict[str, LookupTable]:
    """Algorithm 1 outer loop over the UE set."""
    return {name: pso_vectorized(p, ue, server, weights, cons, tp_max_mbps)
            for name, p in profiles.items()}
