"""Split-boundary activation codec (beyond-paper, JALAD-inspired).

The head quantises the intermediate activation before transmission; the tail
dequantises. data_size(l) scales with the codec ratio, which changes the PSO
tables — deeper splits tolerate lower bitwidths (features are more abstract).
Pure-jnp here; the int8 path has a Pallas kernel (repro/kernels/quant).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class Codec:
    name: str
    bits: int

    @property
    def ratio(self) -> float:
        """bytes(coded)/bytes(bf16 reference)."""
        return self.bits / 16.0


FP16 = Codec("fp16", 16)
INT8 = Codec("int8", 8)
INT4 = Codec("int4", 4)


def rowwise_quant(x: jax.Array, qmax: int):
    """Symmetric per-row (last dim) integer quantisation. Returns (q, scale).

    The single home of the formula: the boundary codec, the KV-cache int8
    path (models/blocks), and the Pallas kernel oracle (kernels/quant/ref)
    all route here. Scale uses an explicit reciprocal multiply to stay
    bit-identical with the Pallas kernel, whose fused divide-by-constant
    XLA rewrites that way (a 1-ULP scale skew flips round())."""
    amax = jnp.max(jnp.abs(x.astype(F32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) * jnp.float32(1.0 / qmax)
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -qmax - 1, qmax)
    return q.astype(jnp.int8), scale


def quantize(x: jax.Array, bits: int):
    """Split-boundary codec entry point. Returns (q, scale)."""
    assert bits in (4, 8)
    return rowwise_quant(x, 2 ** (bits - 1) - 1)


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    return (q.astype(F32) * scale).astype(dtype)


def roundtrip(x: jax.Array, codec: Codec) -> jax.Array:
    if codec.bits >= 16:
        return x.astype(jnp.bfloat16).astype(x.dtype)
    q, s = quantize(x, codec.bits)
    return dequantize(q, s, x.dtype)


def transmit_bytes(shape, codec: Codec) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    payload = n * codec.bits // 8
    if codec.bits < 16:  # per-channel fp32 scales
        payload += 4 * n // int(shape[-1])
    return payload
