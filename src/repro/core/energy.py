"""Device profiles + the paper's UE energy model: E_UE = TDP/threads * t."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    flops_per_s: float  # effective sustained rate
    tdp_w: float  # thermal design power
    threads: int
    fixed_latency_s: float = 0.0  # invocation overhead (RPC, batching)

    def compute_time(self, flops: float) -> float:
        return self.fixed_latency_s + flops / self.flops_per_s

    def energy(self, compute_time_s: float) -> float:
        """Joules for a compute interval (paper Sec. V: TDP/threads * t)."""
        return self.tdp_w / self.threads * compute_time_s


# paper testbed: UE = 2-core 4GB VM behind a 5G dongle; edge = 2xA40 server.
# UE rate calibrated so Fig. 6's jamming pair reproduces simultaneously:
# fixed ~1.66s at ~9 Mbps needs d_ue(pool2) ~0.18s and adaptive ~0.59s needs
# d_ue(deep) ~0.59s => ~52 GFLOP/s effective (2 AVX-512 cores).
UE_VM_2CORE = DeviceProfile("ue-vm-2core", flops_per_s=52e9, tdp_w=28.0,
                            threads=2, fixed_latency_s=0.0)
EDGE_A40X2 = DeviceProfile("edge-2xa40", flops_per_s=8e12, tdp_w=300.0,
                           threads=64, fixed_latency_s=0.004)

# TPU-native reinterpretation (split serving across pod partitions)
UE_TPU_PARTITION = DeviceProfile("ue-pod", flops_per_s=0.4 * 197e12 * 256,
                                 tdp_w=170.0 * 256, threads=256,
                                 fixed_latency_s=0.0005)
EDGE_TPU_PARTITION = DeviceProfile("edge-pod", flops_per_s=0.4 * 197e12 * 256,
                                   tdp_w=170.0 * 256, threads=256,
                                   fixed_latency_s=0.0005)
