"""Privacy leakage metric: distance correlation (Székely dCor), as used by
the paper (via NoPeek [12]) between input images and intermediate activations.

dCor in [0,1]; lower = less information about the input leaks through the
transmitted features. Pure-jnp oracle here; the O(n^2 d) pairwise-distance
hot spot has a Pallas kernel in repro/kernels/dcor (ops.pairwise_dists).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def pairwise_dists(x: jax.Array) -> jax.Array:
    """Euclidean distance matrix. x: (n, d) -> (n, n).

    Self-distances are pinned to exact 0: the ||a||^2+||b||^2-2ab
    expansion cancels catastrophically on the diagonal and sqrt amplifies
    the residue to ~1e-3."""
    x = x.astype(F32)
    sq = jnp.sum(x * x, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    d2 = jnp.where(jnp.eye(x.shape[0], dtype=bool), 0.0, d2)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def _double_center(a: jax.Array) -> jax.Array:
    rm = a.mean(axis=0, keepdims=True)
    cm = a.mean(axis=1, keepdims=True)
    return a - rm - cm + a.mean()


def dcov2(a_centered, b_centered) -> jax.Array:
    return jnp.mean(a_centered * b_centered)


def dcor(x: jax.Array, y: jax.Array, *, dist_fn=pairwise_dists) -> jax.Array:
    """Distance correlation between samples x: (n, dx) and y: (n, dy)."""
    a = _double_center(dist_fn(x.reshape(x.shape[0], -1)))
    b = _double_center(dist_fn(y.reshape(y.shape[0], -1)))
    dxy = dcov2(a, b)
    dxx = dcov2(a, a)
    dyy = dcov2(b, b)
    denom = jnp.sqrt(jnp.maximum(dxx * dyy, 1e-30))
    return jnp.sqrt(jnp.maximum(dxy, 0.0) / denom)


dcor_jit = jax.jit(dcor)


def layer_privacy_profile(inputs, activations_by_layer) -> jnp.ndarray:
    """P(l) for every candidate split: dCor(input, activation_l)."""
    vals = []
    for act in activations_by_layer:
        vals.append(dcor_jit(inputs, act))
    return jnp.stack(vals)
