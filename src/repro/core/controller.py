"""Adaptive runtime controller (the AF in Fig. 1).

Consumes throughput estimates from the RAN estimator every 0.1 s, smooths
them (EWMA), queries the PSO lookup table, and re-splits with hysteresis so
transient estimate noise does not thrash the deployment.

The decision logic lives in a pure functional state machine —
``ControllerState`` (a pytree of scalars) advanced by ``controller_step`` —
so a whole fleet of controllers runs as one ``vmap`` over UEs inside one
``lax.scan`` over report periods (see ``repro.sim``). The stateful
``AdaptiveSplitController`` class is a thin wrapper over the same functional
core, so the sequential and batched paths cannot drift apart.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.pso import NO_SPLIT, LookupTable

# ``pending_split`` sentinel. NO_SPLIT (-1) is a legal proposal (when the
# fallback is NO_SPLIT itself), so "nothing pending" needs its own value.
PENDING_NONE = -2

F32 = jnp.float32
I32 = jnp.int32


@dataclasses.dataclass
class ControllerConfig:
    ewma_alpha: float = 0.5  # weight of the newest estimate
    hysteresis_steps: int = 2  # consecutive agreeing decisions to switch
    fallback_split: int = NO_SPLIT  # used when no feasible split exists


class ControllerState(NamedTuple):
    """One controller's full decision state. Every field is a scalar array;
    batching a fleet is adding a leading (N,) axis to each leaf — the pytree
    is what ``vmap``/``scan`` carry."""

    tp_ewma: jax.Array  # f32, EWMA of the throughput estimates (Mbps)
    has_ewma: jax.Array  # bool, False until the first report lands
    current_split: jax.Array  # i32, deployed split (NO_SPLIT allowed)
    pending_split: jax.Array  # i32, proposal under hysteresis; PENDING_NONE
    pending_count: jax.Array  # i32, consecutive agreeing reports
    step: jax.Array  # i32, reports consumed so far


def controller_init(warm_split=NO_SPLIT, batch_shape=()) -> ControllerState:
    """Fresh state, optionally warm-started at a deployed split.

    ``warm_split`` may be a scalar or an (N,)-shaped array; ``batch_shape``
    broadcasts every field for fleet use."""
    warm = jnp.broadcast_to(jnp.asarray(warm_split, I32), batch_shape)
    z = jnp.zeros(batch_shape, I32)
    return ControllerState(
        tp_ewma=jnp.zeros(batch_shape, F32),
        has_ewma=jnp.zeros(batch_shape, bool),
        current_split=warm,
        pending_split=jnp.full(batch_shape, PENDING_NONE, I32),
        pending_count=z,
        step=z,
    )


def controller_step(table: jax.Array, state: ControllerState, tp_mbps,
                    *, cfg: ControllerConfig
                    ) -> tuple[ControllerState, jax.Array]:
    """Advance one controller by one estimator report: (state, tp) ->
    (state, split). Pure, scalar semantics; batch with
    ``jax.vmap(partial(controller_step, cfg=cfg))(tables, states, tps)``
    where ``tables`` is a stacked (U, tp_max+1) array (per-UE rows map
    alongside per-UE states)."""
    tp = jnp.asarray(tp_mbps, F32)  # both paths smooth in f32
    a = cfg.ewma_alpha
    ewma = jnp.where(state.has_ewma,
                     a * tp + (1 - a) * state.tp_ewma, tp).astype(F32)
    # LookupTable.query semantics: round to the integer Mbps bucket, clamp
    # into the table; bucket 0 is never filled by the sweep => NO_SPLIT.
    bucket = jnp.clip(jnp.round(ewma).astype(I32), 0, table.shape[-1] - 1)
    proposal = jnp.take(table, bucket, axis=-1).astype(I32)
    proposal = jnp.where(proposal == NO_SPLIT,
                         jnp.asarray(cfg.fallback_split, I32), proposal)
    differs = proposal != state.current_split
    count = jnp.where(proposal == state.pending_split,
                      state.pending_count + 1, 1)
    switch = differs & (count >= cfg.hysteresis_steps)
    # a switch or a revert-to-current clears the pending proposal entirely;
    # a stale pending_split must never survive (see the class docstring test)
    keep_pending = differs & ~switch
    new = ControllerState(
        tp_ewma=ewma,
        has_ewma=jnp.ones_like(state.has_ewma),
        current_split=jnp.where(switch, proposal, state.current_split),
        pending_split=jnp.where(keep_pending, proposal,
                                jnp.asarray(PENDING_NONE, I32)),
        pending_count=jnp.where(keep_pending, count, 0),
        step=state.step + 1,
    )
    return new, new.current_split


@functools.lru_cache(maxsize=None)
def _jitted_step(ewma_alpha: float, hysteresis_steps: int,
                 fallback_split: int):
    """One compiled step per distinct config — shared by every controller
    instance (a looped fleet must not recompile per UE)."""
    cfg = ControllerConfig(ewma_alpha, hysteresis_steps, fallback_split)
    return jax.jit(functools.partial(controller_step, cfg=cfg))


class AdaptiveSplitController:
    """Stateful convenience wrapper over ``controller_step`` (one UE)."""

    def __init__(self, table: LookupTable,
                 cfg: Optional[ControllerConfig] = None):
        self.table = table
        self.cfg = cfg or ControllerConfig()
        self._table_arr = jnp.asarray(table.table, I32)
        self._step = _jitted_step(self.cfg.ewma_alpha,
                                  self.cfg.hysteresis_steps,
                                  self.cfg.fallback_split)
        self.switches: list[tuple[int, float, int]] = []  # (step, tp, l)
        self.state = controller_init()

    # ---- attribute views kept for callers of the original class ----
    @property
    def tp_ewma(self) -> Optional[float]:
        return float(self.state.tp_ewma) if bool(self.state.has_ewma) else None

    @property
    def current_split(self) -> int:
        return int(self.state.current_split)

    @current_split.setter
    def current_split(self, l: int) -> None:
        # legacy warm-start poke; prefer reset(warm_split=...)
        self.state = self.state._replace(current_split=jnp.asarray(l, I32))

    @property
    def pending_split(self) -> Optional[int]:
        p = int(self.state.pending_split)
        return None if p == PENDING_NONE else p

    @property
    def pending_count(self) -> int:
        return int(self.state.pending_count)

    def reset(self, warm_split: int = NO_SPLIT) -> None:
        """Return to a fresh state, deployed at ``warm_split`` (the AF warm
        start: reports streamed before this window already settled the
        split). Clears the EWMA, hysteresis and switch history."""
        self.state = controller_init(warm_split)
        self.switches = []

    def update(self, tp_estimate_mbps: float) -> int:
        """Feed one estimator report; returns the split to use now."""
        prev = int(self.state.current_split)
        step = int(self.state.step)
        self.state, split = self._step(self._table_arr, self.state,
                                       float(tp_estimate_mbps))
        l = int(split)
        if l != prev:
            self.switches.append((step, float(self.state.tp_ewma), l))
        return l
