"""Adaptive runtime controller (the AF in Fig. 1).

Consumes throughput estimates from the RAN estimator every 0.1 s, smooths
them (EWMA), queries the PSO lookup table, and re-splits with hysteresis so
transient estimate noise does not thrash the deployment.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.pso import NO_SPLIT, LookupTable


@dataclasses.dataclass
class ControllerConfig:
    ewma_alpha: float = 0.5  # weight of the newest estimate
    hysteresis_steps: int = 2  # consecutive agreeing decisions to switch
    fallback_split: int = NO_SPLIT  # used when no feasible split exists


class AdaptiveSplitController:
    def __init__(self, table: LookupTable,
                 cfg: Optional[ControllerConfig] = None):
        self.table = table
        self.cfg = cfg or ControllerConfig()
        self.tp_ewma: Optional[float] = None
        self.current_split: int = NO_SPLIT
        self.pending_split: Optional[int] = None  # None = nothing pending
        self.pending_count = 0
        self.switches: list[tuple[int, float, int]] = []  # (step, tp, l)
        self._step = 0

    def _clear_pending(self) -> None:
        self.pending_split = None
        self.pending_count = 0

    def update(self, tp_estimate_mbps: float) -> int:
        """Feed one estimator report; returns the split to use now."""
        a = self.cfg.ewma_alpha
        self.tp_ewma = (tp_estimate_mbps if self.tp_ewma is None
                        else a * tp_estimate_mbps + (1 - a) * self.tp_ewma)
        proposal = self.table.query(self.tp_ewma)
        if proposal == NO_SPLIT:
            proposal = self.cfg.fallback_split
        if proposal != self.current_split:
            if proposal == self.pending_split:
                self.pending_count += 1
            else:
                self.pending_split = proposal
                self.pending_count = 1
            if self.pending_count >= self.cfg.hysteresis_steps:
                self.current_split = proposal
                self.switches.append((self._step, self.tp_ewma, proposal))
                self._clear_pending()
        else:
            # proposal reverted to the deployed split: drop the pending
            # proposal entirely, not just its count — a stale pending_split
            # would let a later lone agreeing report look like progress
            # toward a switch that was already abandoned.
            self._clear_pending()
        self._step += 1
        return self.current_split
