"""Head/tail model partitioning — the mechanism the PSO tables drive.

VGG16: split at any of the 43 op boundaries.
LM architectures: split at megablock boundaries (pattern repeats); the head
runs embed + groups[:k], the tail groups[k:] + remainder + logits. At pod
scale the boundary crossing is the inter-pod link; the codec (core/boundary)
shrinks the transmitted activation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import boundary
from repro.dist.sharding import constrain
from repro.models import blocks as B
from repro.models import lm
from repro.models import vgg as vggmod

F32 = jnp.float32


# ------------------------------------------------------------------ VGG split
def vgg_head(vcfg, params, images, l: int):
    """Ops [0, l) on the UE. Returns the intermediate activation."""
    return vggmod.forward(vcfg, params, images, start=0, stop=l)


def vgg_tail(vcfg, params, act, l: int):
    return vggmod.forward(vcfg, params, act, start=l, stop=43)


def vgg_split_infer(vcfg, params, images, l: int,
                    codec: boundary.Codec = boundary.FP16):
    """End-to-end split inference incl. boundary codec round-trip."""
    act = vgg_head(vcfg, params, images, l)
    act = boundary.roundtrip(act, codec)
    return vgg_tail(vcfg, params, act, l)


# ------------------------------------------------------------------ LM split
def lm_split_points(cfg) -> list[int]:
    """Valid split indices in megablock units (1..n_full)."""
    return list(range(1, cfg.n_full_patterns + 1))


def _slice_groups(params, lo, hi):
    return jax.tree.map(lambda t: t[lo:hi], params["groups"])


def lm_head(cfg, params, batch, k: int, *, dtype=jnp.bfloat16):
    """embed + pattern-groups [0, k) -> residual activation (B, S, D)."""
    x = lm.embed_in(cfg, params, batch, dtype)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    vision = batch.get("vision")

    def body(carry, gparams):
        x = carry
        for i, spec in enumerate(cfg.pattern):
            x, _, _ = lm.apply_block(cfg, spec, gparams[i], x, mode="train",
                                     positions=positions, vision=vision,
                                     dtype=dtype)
        return x, None

    x, _ = jax.lax.scan(body, x, _slice_groups(params, 0, k))
    return constrain(x, ("batch", "ctx", "embed"))


def lm_tail(cfg, params, act, batch, k: int, *, dtype=jnp.bfloat16,
            logits_mode="last"):
    """pattern-groups [k, n_full) + remainder + logits."""
    x = act.astype(dtype)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    vision = batch.get("vision")

    def body(carry, gparams):
        x = carry
        for i, spec in enumerate(cfg.pattern):
            x, _, _ = lm.apply_block(cfg, spec, gparams[i], x, mode="train",
                                     positions=positions, vision=vision,
                                     dtype=dtype)
        return x, None

    x, _ = jax.lax.scan(body, x, _slice_groups(params, k,
                                               cfg.n_full_patterns))
    for spec, p in zip(cfg.remainder, params["rem"]):
        x, _, _ = lm.apply_block(cfg, spec, p, x, mode="train",
                                 positions=positions, vision=vision,
                                 dtype=dtype)
    if logits_mode == "last":
        x = x[:, -1:]
    return lm.logits_out(cfg, params, x, dtype)


def lm_split_infer(cfg, params, batch, k: int,
                   codec: boundary.Codec = boundary.FP16,
                   *, dtype=jnp.bfloat16, logits_mode="last"):
    """Reference split inference (single runtime). The production path runs
    head and tail as separate jits on pod submeshes — see launch/serve.py."""
    act = lm_head(cfg, params, batch, k, dtype=dtype)
    act = boundary.roundtrip(act, codec)
    return lm_tail(cfg, params, act, batch, k, dtype=dtype,
                   logits_mode=logits_mode)


def boundary_bytes(cfg, seq: int, batch: int, codec: boundary.Codec) -> int:
    return boundary.transmit_bytes((batch, seq, cfg.d_model), codec)
