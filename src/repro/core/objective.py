"""The paper's joint objective F(l, TP) and constraints (Sec. V).

F(l,TP) = w1*D_E2E(l,TP) + w2*P(l) + w3*E_UE(l)
D_E2E   = d_UE(l) + d_TRX(l,TP) + d_ser(l)
s.t. D_E2E <= tau_max, P <= rho_max, E_UE <= E_max.

Everything is vectorised over (l, TP) so lookup-table construction is one
matrix pass (numpy for the host-side planner; jnp mirrors for tests).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.energy import DeviceProfile
from repro.core.profiles import SplitProfile

INFEASIBLE = np.inf


@dataclasses.dataclass(frozen=True)
class Constraints:
    tau_max_s: float = np.inf  # latency
    rho_max: float = 1.0  # privacy (dCor)
    e_max_j: float = np.inf  # UE energy


@dataclasses.dataclass(frozen=True)
class Weights:
    """w1..w3. The paper normalises each metric so contributions balance;
    normalise=True divides by the metric's per-profile max."""

    w_delay: float = 1.0
    w_privacy: float = 0.0
    w_energy: float = 0.0
    normalize: bool = True


@dataclasses.dataclass
class ObjectiveTerms:
    d_ue: np.ndarray  # (L,)
    d_ser: np.ndarray  # (L,)
    d_trx: np.ndarray  # (L, T)
    d_e2e: np.ndarray  # (L, T)
    privacy: np.ndarray  # (L,)
    e_ue: np.ndarray  # (L,)
    f: np.ndarray  # (L, T)
    feasible: np.ndarray  # (L, T) bool


def evaluate(profile: SplitProfile, ue: DeviceProfile, server: DeviceProfile,
             tp_bps: np.ndarray, weights: Weights,
             cons: Constraints) -> ObjectiveTerms:
    tp_bps = np.asarray(tp_bps, float)
    d_ue = profile.d_ue(ue)
    d_ser = profile.d_ser(server)
    d_trx = profile.d_trx(tp_bps)
    d_e2e = d_ue[:, None] + d_ser[:, None] + d_trx
    p = profile.privacy
    e = profile.e_ue(ue)
    if weights.normalize:
        nd = max(float(np.max(d_ue + d_ser)), 1e-9)
        np_ = max(float(np.max(p)), 1e-9)
        ne = max(float(np.max(e)), 1e-9)
    else:
        nd = np_ = ne = 1.0
    f = (weights.w_delay * d_e2e / nd
         + weights.w_privacy * (p / np_)[:, None]
         + weights.w_energy * (e / ne)[:, None])
    feasible = ((d_e2e <= cons.tau_max_s)
                & (p <= cons.rho_max)[:, None]
                & (e <= cons.e_max_j)[:, None])
    return ObjectiveTerms(d_ue, d_ser, d_trx, d_e2e, p, e,
                          np.where(feasible, f, INFEASIBLE), feasible)
