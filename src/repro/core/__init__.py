# The paper's primary contribution: adaptive, constraint-filtered model
# partitioning (PSO lookup tables + dCor privacy + UE energy model), driven
# by the AI throughput estimator (repro/estimator) over simulated 5G channels
# (repro/channel), applied to VGG16 and every assigned LM architecture
# (core/splitting) with a quantising boundary codec (core/boundary).
from repro.core import (  # noqa: F401
    boundary,
    controller,
    energy,
    objective,
    privacy,
    profiles,
    pso,
    splitting,
)
