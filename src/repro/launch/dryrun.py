import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (XLA_FLAGS must precede every other import, incl. repro.*)
import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / (
    "results") / "dryrun"


def run_cell(arch: str, shape: str, multi_pod: bool, remat: str,
             overrides: dict | None, grad_accum: int | None,
             calibrate: bool = True, kv_dtype: str = "bf16",
             bf16_gather: bool = False, weight_dtype: str = "bf16") -> dict:
    import jax  # noqa: F401  (after XLA_FLAGS)

    from repro.configs import SHAPES, cell_status, get_config
    from repro.dist import sharding as sh
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell, compile_lowered, lower_cell

    import dataclasses as _dc

    from repro.launch.steps import serve_overrides

    cfg = get_config(arch)
    if kv_dtype != "bf16":
        cfg = _dc.replace(cfg, kv_dtype=kv_dtype)
    status = cell_status(arch, shape)
    if SHAPES[shape][2] in ("prefill", "decode"):
        overrides = {**serve_overrides(cfg), **(overrides or {})} or None
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16", "status": status,
           "remat": remat, "overrides": overrides or {},
           "kv_dtype": kv_dtype, "bf16_gather": bf16_gather,
           "weight_dtype": weight_dtype}
    if status != "ok":
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with sh.use_rules(mesh, overrides) as rs:
        cell = build_cell(cfg, shape, rs, remat=remat, grad_accum=grad_accum,
                          bf16_gather=bf16_gather, weight_dtype=weight_dtype)
        lowered = lower_cell(cell, mesh, overrides)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = compile_lowered(lowered)
        rec["compile_s"] = round(time.time() - t1, 2)
        rec["grad_accum"] = cell.grad_accum
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = str(mem)
        from repro.launch.steps import cost_analysis_dict
        rec["cost_analysis"] = {
            k: v for k, v in cost_analysis_dict(compiled).items()
            if k in ("flops", "bytes accessed", "transcendentals")
        }
    costvec = None
    if calibrate and not multi_pod:
        # loop-corrected costs via unrolled 1x/2x-pattern compiles (pod1 only:
        # the roofline table is single-pod; pod2 proves sharding coherence)
        t2 = time.time()
        costvec = rl.calibrated_costs(cfg, shape, mesh, overrides,
                                      remat=remat, grad_accum=cell.grad_accum,
                                      bf16_gather=bf16_gather)
        rec["calibrate_s"] = round(time.time() - t2, 2)
    rec["roofline"] = rl.roofline(compiled, mesh, cfg, shape, SHAPES,
                                  cell.grad_accum, costvec=costvec)
    return rec


def cell_path(arch: str, shape: str, multi_pod: bool) -> pathlib.Path:
    mesh = "pod2" if multi_pod else "pod1"
    return RESULTS_DIR / mesh / f"{arch}__{shape}.json"


def main() -> int:
    ap = argparse.ArgumentParser(description="multi-pod AOT dry-run")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape x mesh) in subprocesses")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--overrides", default=None,
                    help='JSON dict of sharding-rule overrides')
    ap.add_argument("--tag", default=None,
                    help="write result to a tagged filename (perf experiments)")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--kv-dtype", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--weight-dtype", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--bf16-gather", action="store_true")
    args = ap.parse_args()

    if args.all:
        from repro.configs import SHAPES, list_archs
        failures = []
        for arch in list_archs():
            for shape in SHAPES:
                for mp in (False, True):
                    out = cell_path(arch, shape, mp)
                    if out.exists() and not args.force:
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--remat", args.remat]
                    if mp:
                        cmd.append("--multi-pod")
                    if args.force:
                        cmd.append("--force")
                    print(f"[dryrun] {arch} {shape} "
                          f"{'pod2' if mp else 'pod1'}", flush=True)
                    r = subprocess.run(cmd, env={**os.environ,
                                                 "PYTHONPATH": "src"})
                    if r.returncode:
                        failures.append((arch, shape, mp))
        print(f"[dryrun] sweep done, {len(failures)} failures: {failures}")
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch and --shape required"
    overrides = json.loads(args.overrides) if args.overrides else None
    out = cell_path(args.arch, args.shape, args.multi_pod)
    if args.tag:
        out = out.with_name(out.stem + f"__{args.tag}.json")
    if out.exists() and not args.force:
        print(f"[dryrun] cached: {out}")
        return 0
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, args.remat,
                       overrides, args.grad_accum,
                       calibrate=not args.no_calibrate,
                       kv_dtype=args.kv_dtype, bf16_gather=args.bf16_gather,
                       weight_dtype=args.weight_dtype)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "status": "error", "traceback": traceback.format_exc()}
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(rec, indent=1))
        print(rec["traceback"], file=sys.stderr)
        return 1
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    r = rec.get("roofline", {})
    print(json.dumps({k: rec.get(k) for k in
                      ("arch", "shape", "mesh", "status", "lower_s",
                       "compile_s", "grad_accum")}, indent=1))
    if r:
        print(f"  t_compute={r['t_compute_s']:.4f}s t_memory="
              f"{r['t_memory_s']:.4f}s t_collective={r['t_collective_s']:.4f}s"
              f" bottleneck={r['bottleneck']} useful={r['useful_flops_ratio']:.3f}"
              f" fits16GB={r.get('fits_16gb_hbm')}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
