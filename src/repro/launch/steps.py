"""Step functions + abstract specs for every (arch × shape) cell.

Everything here is shape-driven: the dry-run lowers these with
ShapeDtypeStruct stand-ins (no allocation); examples/tests call them with
real arrays on tiny configs.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig
from repro.dist import sharding as sh
from repro.models import lm, template as T
from repro.optim import AdamW

F32 = jnp.float32

# per-device bytes we budget for scan-saved activation carries (v5e ~16GB)
CARRY_BUDGET = 6e9


# ------------------------------------------------------------------ batch specs
def batch_template(cfg: ModelConfig, shape_name: str, rows: int | None = None):
    """ParamSpec tree describing the *global* input batch of a cell."""
    seq, gb, kind = SHAPES[shape_name]
    if rows is not None:
        gb = rows
    t = {}
    if kind == "decode":
        if cfg.frame_input_dim:
            raise ValueError("encoder archs have no decode step")
        t["tokens"] = T.ParamSpec((gb, 1), ("batch", None), jnp.int32, "zeros")
        return t
    if cfg.frame_input_dim:
        t["frames"] = T.ParamSpec((gb, seq, cfg.frame_input_dim),
                                  ("batch", "seq", None), jnp.bfloat16, "normal")
    else:
        t["tokens"] = T.ParamSpec((gb, seq), ("batch", "seq"), jnp.int32, "zeros")
    if kind == "train":
        t["labels"] = T.ParamSpec((gb, seq), ("batch", "seq"), jnp.int32, "zeros")
    if cfg.vision_dim:
        t["vision"] = T.ParamSpec((gb, cfg.vision_tokens, cfg.vision_dim),
                                  ("batch", None, None), jnp.bfloat16, "normal")
    return t


def serve_param_template(cfg: ModelConfig, weight_dtype: str = "bf16"):
    """Inference weights: bf16, or W8A16 (int8 matrix weights dequantised at
    use; per-channel scales add <1% bytes and are omitted from the dry-run
    shape model)."""
    int8 = weight_dtype == "int8"

    def conv(s):
        if int8 and len(s.shape) >= 2:
            return dataclasses.replace(s, dtype=jnp.int8)
        return dataclasses.replace(s, dtype=jnp.bfloat16)

    return jax.tree.map(conv, lm.model_template(cfg), is_leaf=T.is_spec)


def serve_overrides(cfg: ModelConfig, model_shards: int = 16) -> dict:
    """Serving sharding policy: replicate weights across 'data' (pure TP,
    no per-token FSDP all-gathers) whenever the bf16 weights fit one TP
    group's HBM; the MoE/90B giants keep 2D weight sharding (weight-gather
    serving) on non-EP meshes. On an EP mesh (``make_production_mesh(
    ep=True)``) the ``experts`` rule resolves and MoE expert weights shard
    E-ways over 'expert' with no override needed."""
    out: dict = {}
    bf16_bytes = cfg.param_count() * 2
    if bf16_bytes / model_shards < 10e9:
        out["fsdp"] = None
    if cfg.n_heads and cfg.n_heads % model_shards:
        # kv heads can't shard over 'model': shard the cache SEQ dim there
        # instead of replicating the whole KV cache 16x per device
        out["cache_seq"] = "model"
    return out


def opt_state_template(cfg: ModelConfig):
    pt = lm.model_template(cfg)
    return {
        "m": pt,
        "v": pt,
        "step": T.ParamSpec((), (), jnp.int32, "zeros"),
    }


def train_state_template(cfg: ModelConfig):
    return {"params": lm.model_template(cfg), "opt": opt_state_template(cfg)}


def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    return T.abstract_from_template(batch_template(cfg, shape_name))


# ------------------------------------------------------------------ grad accum
def pick_grad_accum(cfg: ModelConfig, shape_name: str,
                    ruleset: Optional[sh.Ruleset] = None) -> int:
    seq, gb, kind = SHAPES[shape_name]
    if kind != "train":
        return 1
    rs = ruleset or sh.active()
    dp = 1
    if rs is not None:
        dp = rs.axis_size("data") * rs.axis_size("pod")
    carry = cfg.n_layers * cfg.d_model * 2 * gb * seq / max(dp, 1)
    if any(b.kind == "ssd" for b in cfg.pattern):
        # SSD within-chunk tiles: ~two dozen fp32 (tokens*nh*L_chunk) buffers
        # live during one layer's backward (the Pallas ssd kernel keeps these
        # in VMEM on real TPU; the XLA fallback materialises them)
        nh = max(cfg.d_inner // max(cfg.ssm_headdim, 1), 1)
        ssd = 24 * 4 * (gb * seq / max(dp, 1)) * nh * cfg.ssm_chunk
        carry = max(carry, ssd)
    if any(b.kind == "rec" for b in cfg.pattern):
        # RG-LRU associative scans hold ~2 fp32 tensors per log2(seq) level
        # transiently during the backward pass of each microbatch
        levels = max(1, math.ceil(math.log2(max(seq, 2))))
        assoc = 2 * 4 * levels * gb * seq * (cfg.lru_width or cfg.d_model)
        carry = max(carry, assoc / max(dp, 1))
    need = max(1, math.ceil(carry / CARRY_BUDGET))
    n = 1
    while n < need:
        n *= 2
    # keep at least one example per data shard in each microbatch
    n = min(n, max(1, gb // max(dp, 1)))
    while gb % n:
        n //= 2
    return max(n, 1)


# ------------------------------------------------------------------ steps
def make_train_step(cfg: ModelConfig, opt: AdamW, *, remat="full",
                    grad_accum: int = 1, unroll: bool = False,
                    bf16_gather: bool = False):
    def loss_fn(params, mb):
        loss, metrics = lm.lm_loss(cfg, params, mb, remat=remat, unroll=unroll)
        return loss, metrics

    def maybe_cast(params):
        # §Perf optimization: casting the stacked fp32 master weights to bf16
        # BEFORE the layer scan halves every FSDP all-gather inside it (the
        # gather then moves bf16 slices); grads still flow to fp32 masters.
        if not bf16_gather:
            return params
        return jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if p.dtype == jnp.float32 else p, params)

    # §Perf: pin every per-microbatch gradient to the parameter sharding so
    # the cross-'data' reduction lowers as reduce-scatter onto the FSDP
    # shards (≈1x bytes) instead of all-reduce of the full tensor (≈2x).
    ptmpl = lm.model_template(cfg)

    def shard_grads(g):
        if sh.active() is None:
            return g
        return jax.tree.map(lambda gg, s: sh.constrain(gg, s.axes), g, ptmpl)

    def train_step(state, batch):
        params = state["params"]

        def cast_loss_fn(p, mb):
            return loss_fn(maybe_cast(p), mb)

        if grad_accum == 1:
            (loss, _), grads = jax.value_and_grad(cast_loss_fn, has_aux=True)(
                params, batch)
            grads = shard_grads(grads)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def micro(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(cast_loss_fn, has_aux=True)(
                    params, mb)
                g = shard_grads(g)
                gsum = jax.tree.map(lambda a, b: a + b.astype(F32), gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            (gsum, lsum), _ = lax.scan(micro, (zeros, jnp.zeros((), F32)), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
        new_params, new_opt, om = opt.update(grads, state["opt"], params)
        metrics = {"loss": loss.astype(F32), **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: int, unroll: bool = False):
    def prefill_step(params, batch):
        logits, _, cache = lm.forward(cfg, params, batch, mode="prefill",
                                      remat="none", logits_mode="last",
                                      max_seq=max_seq, unroll=unroll)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, unroll: bool = False):
    def decode_step(params, cache, tokens, pos):
        return lm.decode_step(cfg, params, cache, tokens, pos, unroll=unroll)

    return decode_step


def make_grad_step(cfg: ModelConfig, *, remat="full", unroll=False):
    """value_and_grad only (no optimizer) — used to isolate the optimizer
    term in roofline calibration."""

    def grad_step(params, batch):
        def loss_fn(p):
            return lm.lm_loss(cfg, p, batch, remat=remat, unroll=unroll)[0]

        return jax.value_and_grad(loss_fn)(params)

    return grad_step


# ------------------------------------------------------------------ cell assembly
@dataclasses.dataclass
class Cell:
    """Everything needed to lower one (arch × shape) combination."""

    cfg: ModelConfig
    shape_name: str
    step_fn: object
    in_abstract: tuple
    in_shardings: tuple
    out_shardings: object
    donate_argnums: tuple
    grad_accum: int
    static_meta: dict


def _shardings(tmpl, rs):
    return T.shardings_from_template(tmpl, rs)


def build_cell(cfg: ModelConfig, shape_name: str, rs: sh.Ruleset, *,
               remat="full", grad_accum: Optional[int] = None,
               bf16_gather: bool = False,
               weight_dtype: str = "bf16") -> Cell:
    seq, gb, kind = SHAPES[shape_name]
    bt = batch_template(cfg, shape_name)
    if kind == "train":
        ga = grad_accum or pick_grad_accum(cfg, shape_name, rs)
        st = train_state_template(cfg)
        opt = AdamW()
        step = make_train_step(cfg, opt, remat=remat, grad_accum=ga,
                               bf16_gather=bf16_gather)
        state_sh = _shardings(st, rs)
        repl = NamedSharding(rs.mesh, P())
        metrics_sh = {"loss": repl, "grad_norm": repl, "lr": repl}
        return Cell(cfg, shape_name, step,
                    (T.abstract_from_template(st), T.abstract_from_template(bt)),
                    (state_sh, _shardings(bt, rs)),
                    (state_sh, metrics_sh), (0,), ga,
                    {"kind": kind, "seq": seq, "global_batch": gb})
    pt = serve_param_template(cfg, weight_dtype)
    if kind == "prefill":
        step = make_prefill_step(cfg, max_seq=seq)
        ct = lm.cache_template(cfg, gb, seq)
        return Cell(cfg, shape_name, step,
                    (T.abstract_from_template(pt), T.abstract_from_template(bt)),
                    (_shardings(pt, rs), _shardings(bt, rs)),
                    (None, _shardings(ct, rs)), (), 1,
                    {"kind": kind, "seq": seq, "global_batch": gb})
    # decode: one new token against a cache of length seq
    step = make_decode_step(cfg)
    ct = lm.cache_template(cfg, gb, seq)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    repl = NamedSharding(rs.mesh, P())
    return Cell(cfg, shape_name, step,
                (T.abstract_from_template(pt), T.abstract_from_template(ct),
                 T.abstract_from_template(bt)["tokens"], pos),
                (_shardings(pt, rs), _shardings(ct, rs),
                 _shardings(bt, rs)["tokens"], repl),
                (None, _shardings(ct, rs)), (1,), 1,
                {"kind": kind, "seq": seq, "global_batch": gb})


def build_calibration_cell(cfg: ModelConfig, shape_name: str, rs: sh.Ruleset,
                           *, n_layers: int, variant: str, remat="full",
                           micro_rows: Optional[int] = None,
                           bf16_gather: bool = False) -> Cell:
    """Unrolled reduced-layer cell for cost calibration.

    variant: 'train' (one full step, ga=1) | 'grad' (no optimizer) |
             'prefill' | 'decode'. micro_rows replaces the global batch for
    train variants (the per-microbatch row count)."""
    cfg_k = dataclasses.replace(cfg, n_layers=n_layers)
    seq, gb, kind = SHAPES[shape_name]
    rows = micro_rows if kind == "train" else None
    bt = batch_template(cfg_k, shape_name, rows)
    meta = {"kind": kind, "seq": seq, "global_batch": rows or gb,
            "calibration": variant, "n_layers": n_layers}
    if variant == "train":
        st = train_state_template(cfg_k)
        step = make_train_step(cfg_k, AdamW(), remat=remat, grad_accum=1,
                               unroll=True, bf16_gather=bf16_gather)
        return Cell(cfg_k, shape_name, step,
                    (T.abstract_from_template(st), T.abstract_from_template(bt)),
                    (_shardings(st, rs), _shardings(bt, rs)),
                    None, (0,), 1, meta)
    if variant == "grad":
        pt = lm.model_template(cfg_k)
        step = make_grad_step(cfg_k, remat=remat, unroll=True)
        return Cell(cfg_k, shape_name, step,
                    (T.abstract_from_template(pt), T.abstract_from_template(bt)),
                    (_shardings(pt, rs), _shardings(bt, rs)),
                    None, (), 1, meta)
    pt = serve_param_template(cfg_k)
    if variant == "prefill":
        step = make_prefill_step(cfg_k, max_seq=seq, unroll=True)
        return Cell(cfg_k, shape_name, step,
                    (T.abstract_from_template(pt), T.abstract_from_template(bt)),
                    (_shardings(pt, rs), _shardings(bt, rs)),
                    None, (), 1, meta)
    if variant == "decode":
        step = make_decode_step(cfg_k, unroll=True)
        ct = lm.cache_template(cfg_k, gb, seq)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        repl = NamedSharding(rs.mesh, P())
        return Cell(cfg_k, shape_name, step,
                    (T.abstract_from_template(pt), T.abstract_from_template(ct),
                     T.abstract_from_template(bt)["tokens"], pos),
                    (_shardings(pt, rs), _shardings(ct, rs),
                     _shardings(bt, rs)["tokens"], repl),
                    None, (1,), 1, meta)
    raise ValueError(variant)


def lower_cell(cell: Cell, mesh, overrides: Optional[dict] = None):
    """Trace + lower the cell's step under the mesh's rules. Returns Lowered."""
    with sh.use_rules(mesh, overrides):
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        )
        return jitted.lower(*cell.in_abstract)


# Keep per-layer FSDP all-gathers inside the layer scan: XLA's while-loop LICM
# otherwise hoists them, materialising every layer's gathered weights at once
# (observed: qwen2-72b train temp 19.5GB -> 10.0GB with the pass disabled).
# On real TPU deployments the same is controlled via collective-pipeliner
# tuning; for the AOT dry-run this keeps the memory model deployment-faithful.
COMPILER_OPTS = {"xla_disable_hlo_passes": "while-loop-invariant-code-motion"}

_compiler_opts_ok = True


def compile_lowered(lowered):
    """Compile with COMPILER_OPTS, degrading to defaults on jaxlib builds
    that cannot set repeated DebugOptions fields through compile options
    (proto reflection rejects the string form). The dry-run memory model
    is slightly less deployment-faithful without the LICM pin; tests and
    serving correctness are unaffected."""
    global _compiler_opts_ok
    if _compiler_opts_ok:
        try:
            return lowered.compile(COMPILER_OPTS)
        except RuntimeError as e:
            if "xla_disable_hlo_passes" not in str(e):
                raise
            warnings.warn("this jaxlib cannot apply COMPILER_OPTS "
                          "(repeated DebugOptions field); compiling with "
                          "default passes", RuntimeWarning, stacklevel=2)
            _compiler_opts_ok = False
    return lowered.compile()


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to a flat dict.

    Depending on the jax build the method returns a dict or a one-element
    list of dicts (one per program); every dry-run consumer wants the
    dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
