"""Split-serving launcher: the paper's UE/edge boundary at pod scale.

--dry-run: builds the multi-pod production mesh, slices it into the UE pod
(pod 0) and edge pod (pod 1), lowers + compiles the HEAD program on the UE
submesh and the TAIL program on the edge submesh for every valid split
point, and reports the boundary traffic per codec. This is deliverable (e)'s
split-serving mode: two runtimes + an explicit inter-pod link, exactly how
a disaggregated deployment runs.

--fleet-estimator N: instead AOT-lowers the mesh-sharded fleet estimator
serving program (``repro.sim.serving``) for an N-UE report period on the
single-pod production mesh (``--ep`` swaps in the expert-parallel
``data x expert x model`` variant) and reports the batch sharding, UEs
per chip, and compiled memory footprint.

--fleet-online N: AOT-lowers one online *adaptation* step
(``repro.sim.online``: replay-buffer gather + estimator fwd/bwd + AdamW)
against an N-row buffer on the production mesh — buffer rows sharded over
the data axis, params/optimizer moments replicated — and reports the
minibatch sharding, whether the gradient all-reduce (psum) made it into
the program, and the compiled memory footprint.

Usage:
  python -m repro.launch.serve --dry-run --arch granite-8b --split 18
  python -m repro.launch.serve --fleet-estimator 4096 [--ep]
  python -m repro.launch.serve --fleet-online 65536 [--online-batch 4096]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import get_config
from repro.core import boundary
from repro.core.splitting import lm_head, lm_split_points, lm_tail
from repro.dist import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (compile_lowered, serve_overrides,
                                serve_param_template)
from repro.models import abstract_params
from repro.models.template import shardings_from_template


def pod_submesh(mesh, pod: int) -> Mesh:
    return Mesh(mesh.devices[pod], ("data", "model"))


def fleet_estimator_dryrun(n_ues: int, ep: bool) -> None:
    """Lower + compile one mesh-sharded estimator report period (AOT)."""
    from repro.estimator.model import EstimatorConfig, estimator_template
    from repro.models import template as T
    from repro.sim.serving import ServingMesh, serving_program

    e = EstimatorConfig()
    mesh = make_production_mesh(ep=ep)
    serving = ServingMesh(mesh)
    fn = serving_program(e, serving)
    pabs = T.abstract_from_template(estimator_template(e))
    kpms = jax.ShapeDtypeStruct((n_ues, e.window, e.n_kpms), jnp.float32)
    iq = jax.ShapeDtypeStruct((n_ues, 2, e.n_sc, e.n_sym), jnp.float32)
    alloc = jax.ShapeDtypeStruct((n_ues,), jnp.float32)
    compiled = compile_lowered(fn.lower(pabs, kpms, iq, alloc))
    # resolve the batch sharding the program actually gets: a fleet size
    # not divisible by the data axes falls back to replicated (Ruleset
    # rule 2), and the report must say so rather than claim shards
    rs = sh.Ruleset(mesh, dict(sh.DEFAULT_RULES))
    entry = rs.spec(("batch", None, None), kpms.shape)[0]
    axes = (() if entry is None else
            (entry,) if isinstance(entry, str) else entry)
    batch_shards = 1
    for a in axes:
        batch_shards *= mesh.shape[a]
    print(json.dumps({
        "mode": "fleet-estimator", "mesh": dict(mesh.shape),
        "chips": mesh.size, "n_ues": n_ues,
        "batch_sharded": batch_shards > 1,
        "batch_shards": batch_shards,
        "rows_per_shard": n_ues // batch_shards,
        # with the batch replicated every chip computes the whole fleet,
        # so the per-chip capacity accounting only holds when sharded
        "ue_per_chip": (round(n_ues / mesh.size, 2) if batch_shards > 1
                        else float(n_ues)),
        "memory": str(compiled.memory_analysis()),
    }, indent=1))


def fleet_online_dryrun(n_rows: int, batch: int, ep: bool) -> None:
    """Lower + compile one mesh-sharded online adaptation step (AOT)."""
    from repro.estimator.model import EstimatorConfig, estimator_template
    from repro.estimator.train import make_indexed_step
    from repro.models import template as T
    from repro.optim import AdamW
    from repro.sim.serving import ServingMesh

    e = EstimatorConfig()
    mesh = make_production_mesh(ep=ep)
    serving = ServingMesh(mesh)
    opt = AdamW(lr=1e-3, weight_decay=1e-4, clip_norm=1.0)
    step = make_indexed_step(e, opt, mesh=mesh,
                             overrides=serving.rule_overrides())
    pabs = T.abstract_from_template(estimator_template(e))
    opt_abs = jax.eval_shape(opt.init, pabs)
    rs = sh.Ruleset(mesh, dict(sh.DEFAULT_RULES))

    # buffer rows committed batch-sharded, like sim.online.buffer_init
    def rows(shape):
        return jax.ShapeDtypeStruct(
            shape, jnp.float32,
            sharding=rs.sharding(("batch",) + (None,) * (len(shape) - 1),
                                 shape))
    data = {"kpms": rows((n_rows, e.window, e.n_kpms)),
            "iq": rows((n_rows, 2, e.n_sc, e.n_sym)),
            "alloc": rows((n_rows,)), "tp": rows((n_rows,))}
    idx = jax.ShapeDtypeStruct((batch,), jnp.int32)
    key_abs = jax.eval_shape(jax.random.PRNGKey, 0)
    lowered = step.lower(pabs, opt_abs, data, idx, key_abs)
    compiled = compile_lowered(lowered)
    # the gradient psum is inserted by SPMD partitioning, so it only shows
    # in the compiled (post-partitioning) HLO, not the lowering
    try:
        text = compiled.as_text()
    except Exception:  # pragma: no cover - backend without HLO dump
        text = ""
    spec = rs.spec(("batch", None, None, None), data["iq"].shape)[0]
    axes = (() if spec is None else
            (spec,) if isinstance(spec, str) else spec)
    shards = 1
    for a in axes:
        shards *= mesh.shape[a]
    print(json.dumps({
        "mode": "fleet-online", "mesh": dict(mesh.shape),
        "chips": mesh.size, "buffer_rows": n_rows, "batch": batch,
        "buffer_sharded": shards > 1, "buffer_shards": shards,
        "rows_per_shard": n_rows // shards,
        # the data-parallel gradient psum must be in the program, or the
        # "sharded == unsharded" trainer contract is silently broken
        "grads_psummed": ("all-reduce" in text or "all_reduce" in text),
        "memory": str(compiled.memory_analysis()),
    }, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--split", type=int, default=None,
                    help="megablock split index (default: middle)")
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--codec", default="int8", choices=["fp16", "int8", "int4"])
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--fleet-estimator", type=int, default=0, metavar="N",
                    help="AOT-lower the mesh-sharded fleet estimator "
                    "serving program for an N-UE report period instead of "
                    "the split-serving dry-run")
    ap.add_argument("--fleet-online", type=int, default=0, metavar="N",
                    help="AOT-lower one online adaptation step (buffer "
                    "gather + fwd/bwd + AdamW) against an N-row replay "
                    "buffer on the production mesh")
    ap.add_argument("--online-batch", type=int, default=4096,
                    help="minibatch rows for --fleet-online")
    ap.add_argument("--ep", action="store_true",
                    help="use the expert-parallel production mesh variant "
                    "(data x expert x model) for --fleet-estimator / "
                    "--fleet-online")
    args = ap.parse_args()

    if args.fleet_estimator:
        fleet_estimator_dryrun(args.fleet_estimator, args.ep)
        return
    if args.fleet_online:
        fleet_online_dryrun(args.fleet_online, args.online_batch, args.ep)
        return

    cfg = get_config(args.arch)
    ks = lm_split_points(cfg)
    k = args.split if args.split is not None else ks[len(ks) // 2]
    assert k in ks, f"split {k} not in {ks}"
    codec = {"fp16": boundary.FP16, "int8": boundary.INT8,
             "int4": boundary.INT4}[args.codec]

    mesh = make_production_mesh(multi_pod=True)
    ue, edge = pod_submesh(mesh, 0), pod_submesh(mesh, 1)
    overrides = serve_overrides(cfg)
    batch_abs = {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq),
                                                jnp.int32)}
    if cfg.vision_dim:
        batch_abs["vision"] = jax.ShapeDtypeStruct(
            (args.batch, cfg.vision_tokens, cfg.vision_dim), jnp.bfloat16)

    tmpl = serve_param_template(cfg)
    results = {"arch": args.arch, "split": k, "codec": args.codec}
    with sh.use_rules(ue, overrides) as rs:
        pabs = abstract_params(cfg)
        psh = shardings_from_template(tmpl, rs)
        head = jax.jit(lambda p, b: lm_head(cfg, p, b, k),
                       in_shardings=(psh, None))
        lowered = head.lower(pabs, batch_abs)
        compiled = compile_lowered(lowered)
        results["head_memory"] = str(compiled.memory_analysis())
        act_abs = jax.eval_shape(lambda p, b: lm_head(cfg, p, b, k),
                                 pabs, batch_abs)
    results["boundary_bytes"] = boundary.transmit_bytes(act_abs.shape, codec)
    with sh.use_rules(edge, overrides) as rs:
        psh = shardings_from_template(tmpl, rs)
        tail = jax.jit(lambda p, a, b: lm_tail(cfg, p, a, b, k),
                       in_shardings=(psh, None, None))
        compiled = compile_lowered(tail.lower(pabs, act_abs, batch_abs))
        results["tail_memory"] = str(compiled.memory_analysis())
    ici_bw = 50e9
    results["boundary_transfer_ms"] = round(
        results["boundary_bytes"] / ici_bw * 1e3, 3)
    print(json.dumps(results, indent=1))


if __name__ == "__main__":
    main()
