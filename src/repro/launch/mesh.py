"""Production mesh builders. Functions, not module constants — importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, ep: bool = False):
    """The 256-chip serving/training mesh (512 across two pods).

    ``ep=True`` returns the expert-parallel variant: the same chip count
    factored as ``(data, expert, model)`` so the ``experts`` logical axis
    (see docs/sharding.md) finally resolves to a physical mesh axis and
    MoE expert weights shard E-ways instead of staying 2D-sharded
    (``fsdp x ff``).
    """
    if ep:
        shape = (2, 8, 4, 4) if multi_pod else (16, 4, 4)
        axes = (("pod", "data", "expert", "model") if multi_pod
                else ("data", "expert", "model"))
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, expert: int = 1):
    """Small mesh over whatever devices exist (tests / examples).

    Requested axis sizes are clamped to the host's device count and then
    walked down to divisors, so the resulting grid is always constructible
    — e.g. asking for (16, 16) on a 1-device host yields (1, 1) instead of
    a shape/device-count mismatch. ``expert > 1`` asks for an EP host mesh
    ``(data, expert, model)``; the ``expert`` axis is only materialised
    when its clamped size exceeds 1, so 2-axis callers are unaffected.
    """
    n = max(1, len(jax.devices()))
    data = max(1, min(data, n))
    while n % data:
        data -= 1
    expert = max(1, min(expert, n // data))
    while (n // data) % expert:
        expert -= 1
    model = max(1, min(model, n // (data * expert)))
    while (n // (data * expert)) % model:
        model -= 1
    if expert > 1:
        return jax.make_mesh((data, expert, model),
                             ("data", "expert", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
