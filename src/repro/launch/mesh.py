"""Production mesh builders. Functions, not module constants — importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples).

    Requested axis sizes are clamped to the host's device count and then
    walked down to divisors, so the resulting (data, model) grid is always
    constructible — e.g. asking for (16, 16) on a 1-device host yields
    (1, 1) instead of a shape/device-count mismatch.
    """
    n = max(1, len(jax.devices()))
    data = max(1, min(data, n))
    while n % data:
        data -= 1
    model = max(1, min(model, n // data))
    while (n // data) % model:
        model -= 1
    return jax.make_mesh((data, model), ("data", "model"))
