"""Training launcher: ``python -m repro.launch.train --arch granite-8b
--reduced --steps 50 [--resume]``.

Full configs target the production mesh (real TPU job); --reduced runs the
same code path on host devices for CI / examples.
"""
from __future__ import annotations

import argparse

from repro.configs import get_config
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tc = TrainerConfig(seq=args.seq, global_batch=args.global_batch,
                       steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, lr=args.lr,
                       grad_accum=args.grad_accum)
    trainer = Trainer(cfg, tc)
    _, hist = trainer.run(resume=args.resume)
    for s, l in hist[:: max(1, len(hist) // 10)]:
        print(f"step {int(s):5d} loss {l:.4f}")
    print(f"final loss {hist[-1, 1]:.4f}")


if __name__ == "__main__":
    main()
