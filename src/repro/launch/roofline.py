"""Roofline-term extraction from compiled (AOT) artifacts.

All quantities are PER DEVICE (the SPMD module is the per-device program);
dividing per-device work by per-chip peak rates equals dividing global work
by (chips x peak), so the terms match the spec formulas.

Hardware model: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9_]+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"([a-z][\w\-]*)\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACES_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def type_bytes(t: str) -> int:
    """Bytes of an HLO type string, e.g. 'f32[16,128]{1,0}' or a tuple."""
    total = 0
    for m in re.finditer(r"([a-z0-9_]+)\[([^\]]*)\]", t):
        dt, dims = m.group(1), m.group(2)
        sz = _DTYPE_BYTES.get(dt)
        if sz is None:
            continue
        n = 1
        for d in dims.split(","):
            d = d.strip()
            if d:
                n *= int(d)
        total += n * sz
    return total


@dataclass
class CollectiveStats:
    operand_bytes: float = 0.0
    wire_bytes: float = 0.0
    count: int = 0


def collective_stats(hlo_text: str, n_devices: int):
    """Per-collective-op accounting from post-optimization HLO.

    operand_bytes: sum of operand sizes (spec metric).
    wire_bytes: ring-algorithm bytes actually crossing links per device.
    """
    symtab: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            symtab[m.group(1)] = type_bytes(m.group(2))
    stats: dict[str, CollectiveStats] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, typ, op = m.groups()
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base not in COLLECTIVES:
            continue
        out_bytes = type_bytes(typ)
        # operand sizes via symbol table (fallback: output size)
        ops_str = line[line.index("(") + 1 :]
        depth, j = 1, 0
        while j < len(ops_str) and depth:
            if ops_str[j] == "(":
                depth += 1
            elif ops_str[j] == ")":
                depth -= 1
            j += 1
        operands = [o.strip().lstrip("%") for o in ops_str[: j - 1].split(",")]
        in_bytes = sum(symtab.get(o, 0) for o in operands if o)
        if in_bytes == 0:
            in_bytes = out_bytes
        gm = _GROUPS_RE.search(line)
        if gm:
            gsize = int(gm.group(2))
        else:
            bm = _GROUPS_BRACES_RE.search(line)
            gsize = len(bm.group(1).split(",")) if bm else n_devices
        gsize = max(gsize, 1)
        ring = (gsize - 1) / gsize
        if base == "all-reduce":
            wire = 2 * in_bytes * ring
        elif base == "all-gather":
            wire = out_bytes * ring
        elif base == "reduce-scatter":
            wire = in_bytes * ring
        elif base in ("all-to-all", "ragged-all-to-all"):
            wire = in_bytes * ring
        else:  # collective-permute
            wire = in_bytes
        st = stats.setdefault(base, CollectiveStats())
        st.operand_bytes += in_bytes
        st.wire_bytes += wire
        st.count += 1
    return stats


# ------------------------------------------------------------------ calibration
def _costvec(compiled, n_dev) -> dict:
    from repro.launch.steps import cost_analysis_dict
    ca = cost_analysis_dict(compiled)
    vec = {"flops": float(ca.get("flops", 0.0)),
           "bytes": float(ca.get("bytes accessed", 0.0))}
    stats = collective_stats(compiled.as_text(), n_dev)
    vec["coll_operand"] = sum(s.operand_bytes for s in stats.values())
    vec["coll_wire"] = sum(s.wire_bytes for s in stats.values())
    for k, s in stats.items():
        vec[f"wire:{k}"] = s.wire_bytes
        vec[f"count:{k}"] = float(s.count)
    return vec


def _vec_op(a: dict, b: dict, f) -> dict:
    keys = set(a) | set(b)
    return {k: f(a.get(k, 0.0), b.get(k, 0.0)) for k in keys}


def calibrated_costs(cfg, shape_name: str, mesh, overrides, *, remat="full",
                     grad_accum: int = 1, bf16_gather: bool = False) -> dict:
    """Loop-corrected per-device cost vector.

    XLA's cost analysis counts while-loop bodies ONCE, so the scanned-layer
    full compile undercounts. We compile unrolled 1-pattern and 2-pattern
    variants (still AOT, still the production mesh), take the difference as
    the exact per-pattern cost, and extrapolate linearly in layer count; for
    train we isolate the optimizer term with a grad-only compile so gradient
    accumulation only scales the microbatch part.
    """
    import dataclasses as dc  # noqa: F401

    from repro.configs.base import SHAPES as _SHAPES
    from repro.dist import sharding as sh
    from repro.launch import steps
    from repro.models import blocks

    pat = len(cfg.pattern)
    seq, gb, kind = _SHAPES[shape_name]
    n_dev = mesh.devices.size
    prev_flag = blocks.INNER_UNROLL
    blocks.INNER_UNROLL = True
    try:
        with sh.use_rules(mesh, overrides) as rs:
            def measure(n_layers, variant):
                cell = steps.build_calibration_cell(
                    cfg, shape_name, rs, n_layers=n_layers, variant=variant,
                    remat=remat, bf16_gather=bf16_gather,
                    micro_rows=gb // grad_accum if kind == "train" else None)
                compiled = steps.compile_lowered(
                    steps.lower_cell(cell, mesh, overrides))
                return _costvec(compiled, n_dev)

            if kind == "train":
                c1 = measure(pat, "train")
                c2 = measure(2 * pat, "train")
                cg = measure(pat, "grad")
                per_layer = _vec_op(c2, c1, lambda x, y: max(x - y, 0.0) / pat)
                opt = _vec_op(c1, cg, lambda x, y: max(x - y, 0.0))
                lp = _vec_op(per_layer, {}, lambda x, _: x * pat)
                edge = _vec_op(cg, lp, lambda x, y: max(x - y, 0.0))
                micro = _vec_op(edge, per_layer,
                                lambda e, l: e + l * cfg.n_layers)
                total = _vec_op(micro, opt,
                                lambda m, o: m * grad_accum + o)
            else:
                variant = "prefill" if kind == "prefill" else "decode"
                c1 = measure(pat, variant)
                c2 = measure(2 * pat, variant)
                per_layer = _vec_op(c2, c1, lambda x, y: max(x - y, 0.0) / pat)
                lp = _vec_op(per_layer, {}, lambda x, _: x * pat)
                edge = _vec_op(c1, lp, lambda x, y: max(x - y, 0.0))
                total = _vec_op(edge, per_layer,
                                lambda e, l: e + l * cfg.n_layers)
            total["calibrated"] = 1.0
            return total
    finally:
        blocks.INNER_UNROLL = prev_flag


def model_flops(cfg, shape_name: str, shapes: dict) -> float:
    """6·N_active·tokens (train) or 2·N_active·tokens (serve), global."""
    seq, gb, kind = shapes[shape_name]
    n = cfg.active_param_count()
    tokens = gb * seq if kind in ("train", "prefill") else gb
    mult = 6 if kind == "train" else 2
    return float(mult * n * tokens)


def roofline(compiled, mesh, cfg, shape_name: str, shapes: dict,
             grad_accum: int = 1, costvec: dict | None = None) -> dict:
    """Derive the three roofline terms (seconds, per device == global).

    costvec: loop-corrected costs from calibrated_costs(); when None, raw
    compiled numbers are used (undercounted inside scans)."""
    n_dev = mesh.devices.size
    if costvec is not None:
        flops_dev = costvec["flops"]
        bytes_dev = costvec["bytes"]
        operand_bytes = costvec["coll_operand"]
        wire_bytes = costvec["coll_wire"]
        stats = {k[5:]: CollectiveStats(wire_bytes=v)
                 for k, v in costvec.items() if k.startswith("wire:")}
        for k in stats:
            stats[k].count = int(costvec.get("count:" + k, 0))
    else:
        from repro.launch.steps import cost_analysis_dict
        ca = cost_analysis_dict(compiled)
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(ca.get("bytes accessed", 0.0))
        stats = collective_stats(compiled.as_text(), n_dev)
        operand_bytes = sum(s.operand_bytes for s in stats.values())
        wire_bytes = sum(s.wire_bytes for s in stats.values())
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_collective = wire_bytes / ICI_BW
    mf = model_flops(cfg, shape_name, shapes)
    mf_dev = mf / n_dev
    terms = {
        "chips": n_dev,
        "grad_accum": grad_accum,
        "calibrated": costvec is not None,
        "flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_operand_bytes": operand_bytes,
        "collective_wire_bytes": wire_bytes,
        "collectives": {
            k: {"operand_bytes": s.operand_bytes, "wire_bytes": s.wire_bytes,
                "count": s.count} for k, s in stats.items()
        },
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "model_flops_global": mf,
        "useful_flops_ratio": (mf_dev / flops_dev) if flops_dev else 0.0,
    }
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_collective), key=lambda kv: kv[1])
    terms["bottleneck"] = dom[0]
    step_time = max(t_compute, t_memory, t_collective)
    terms["roofline_step_time_s"] = step_time
    # fraction of compute roofline achieved if the bottleneck were hit
    terms["mfu_bound"] = (mf_dev / PEAK_FLOPS) / step_time if step_time else 0.0
    mem = compiled.memory_analysis()
    if mem is not None:
        terms["memory_per_device"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        }
        live = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        terms["memory_per_device"]["live_bytes"] = int(live)
        terms["fits_16gb_hbm"] = bool(live < 16e9)
    return terms
