"""Llama-3.2-Vision 90B [hf:meta-llama/Llama-3.2-11B-Vision scaled;
unverified]: 100L d8192 64H GQA(kv=8) ff28672 vocab 128256. 80 self-attn
decoder layers with a cross-attention layer after every 4 (pattern SSSSX).
Vision tower is a STUB — input_specs() provides precomputed patch embeddings."""
from repro.configs.base import BlockSpec, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        n_layers=100,
        d_model=8192,
        n_heads=64,
        kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab=128256,
        pattern=(
            BlockSpec(kind="attn"),
            BlockSpec(kind="attn"),
            BlockSpec(kind="attn"),
            BlockSpec(kind="attn"),
            BlockSpec(kind="cross"),
        ),
        vision_dim=1280,
        vision_tokens=1601,  # 1 image tile of 1601 patches (stub frontend)
        rope_theta=500_000.0,
    )
)
