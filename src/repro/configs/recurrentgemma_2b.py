"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf]: 26L d2560 10H
GQA(kv=1, MQA) ff7680 vocab 256000; pattern = 2 RG-LRU recurrent blocks per
1 local-attention (window 2048) block; lru_width 2560."""
from repro.configs.base import BlockSpec, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab=256000,
        pattern=(
            BlockSpec(kind="rec"),
            BlockSpec(kind="rec"),
            BlockSpec(kind="local", window=2048),
        ),
        lru_width=2560,
        conv1d_width=4,
        act="gelu",
        rope_theta=10_000.0,
        tie_embeddings=True,
        logit_softcap=30.0,
    )
)
