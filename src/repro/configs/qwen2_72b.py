"""Qwen2-72B [arXiv:2407.10671; hf]: 80L d8192 64H GQA(kv=8) ff29568
vocab 152064, QKV bias."""
from repro.configs.base import BlockSpec, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-72b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab=152064,
        pattern=(BlockSpec(kind="attn", window=0),),
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )
)
