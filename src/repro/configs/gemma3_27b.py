"""Gemma-3 27B [hf:google/gemma-3 family; unverified]: 62L d5376 32H
GQA(kv=16) ff21504 vocab 262144; 5 local (sliding-window 1024) layers per
1 global layer; 128k context."""
from repro.configs.base import BlockSpec, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab=262144,
        pattern=(
            BlockSpec(kind="local", window=1024),
            BlockSpec(kind="local", window=1024),
            BlockSpec(kind="local", window=1024),
            BlockSpec(kind="local", window=1024),
            BlockSpec(kind="local", window=1024),
            BlockSpec(kind="attn", window=0),  # global
        ),
        act="gelu",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        logit_softcap=30.0,
    )
)
