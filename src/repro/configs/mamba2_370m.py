"""Mamba2-370M [arXiv:2405.21060; unverified]: 48L d1024 attention-free,
SSD (state-space duality) mixer; d_inner 2048 (expand 2), headdim 64
(32 ssm heads), state 128, vocab 50280."""
from repro.configs.base import BlockSpec, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab=50280,
        pattern=(BlockSpec(kind="ssd"),),
        d_inner=2048,
        ssm_state=128,
        ssm_headdim=64,
        ssm_chunk=256,
        ssm_ngroups=1,
        tie_embeddings=True,
    )
)
