"""Config system: every architecture is a ModelConfig; shapes are ShapeConfig.

Block patterns: a model is `pattern * (n_layers // len(pattern))` scanned
megablocks plus `n_layers % len(pattern)` unrolled remainder blocks. Block
kinds:
  attn        causal self-attention (window=0 -> full)  + MLP/MoE
  local       windowed self-attention + MLP
  cross       cross-attention to encoder/vision embeddings + MLP
  rec         RG-LRU recurrent block + MLP
  ssd         Mamba2 state-space-dual block (no MLP; block is the mixer)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence

SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


@dataclass(frozen=True)
class BlockSpec:
    """One position inside the repeated layer pattern."""

    kind: str = "attn"  # attn | local | cross | rec | ssd
    window: int = 0  # 0 = full attention; >0 sliding-window length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    pattern: Sequence[BlockSpec] = (BlockSpec(),)
    causal: bool = True  # False => encoder (bidirectional, no decode)
    qkv_bias: bool = False
    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # VLM cross-attention stub frontend
    vision_dim: int = 0
    vision_tokens: int = 0
    # audio stub frontend (precomputed frame embeddings)
    frame_input_dim: int = 0
    # RG-LRU
    lru_width: int = 0
    conv1d_width: int = 4
    # Mamba2 / SSD
    d_inner: int = 0
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    # misc
    kv_dtype: str = "bf16"  # "int8": quantised KV cache (per-row scales)
    act: str = "silu"
    norm_eps: float = 1e-6
    rope_theta: float = 500_000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived ----
    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def n_full_patterns(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def remainder(self) -> Sequence[BlockSpec]:
        return tuple(self.pattern)[: self.n_layers % len(self.pattern)]

    @property
    def max_window(self) -> int:
        """0 if any block uses full attention, else the largest window."""
        ws = [b.window for b in self.pattern if b.kind in ("attn", "local")]
        if not ws:
            return -1  # attention-free
        return 0 if any(w == 0 for w in ws) else max(ws)

    @property
    def sub_quadratic(self) -> bool:
        """False only for PURE full-attention stacks. Hybrids with windowed /
        recurrent / SSM mixing blocks (gemma3 5:1 local:global, mixtral SWA,
        recurrentgemma, mamba2) qualify for long_500k: their long-context
        state is dominated by the sub-quadratic blocks."""
        return any(
            b.window > 0 or b.kind in ("rec", "ssd") for b in self.pattern
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        c = self
        n = c.vocab * c.d_model  # embedding
        if not c.tie_embeddings:
            n += c.vocab * c.d_model
        for i in range(c.n_layers):
            b = c.pattern[i % len(c.pattern)]
            if b.kind in ("attn", "local", "cross"):
                qkv = c.d_model * (c.n_heads + 2 * c.kv_heads) * c.head_dim
                o = c.n_heads * c.head_dim * c.d_model
                n += qkv + o
                if c.num_experts:
                    n += c.num_experts * 3 * c.d_model * c.d_ff
                    n += c.d_model * c.num_experts  # router
                else:
                    n += 3 * c.d_model * c.d_ff
            elif b.kind == "rec":
                w = c.lru_width or c.d_model
                n += 2 * c.d_model * w + w * c.d_model + 2 * w  # proj + gates
                n += 3 * c.d_model * c.d_ff
            elif b.kind == "ssd":
                nh = c.d_inner // c.ssm_headdim
                n += c.d_model * (2 * c.d_inner + 2 * c.ssm_ngroups * c.ssm_state + nh)
                n += c.d_inner * c.d_model
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if not self.is_moe:
            return self.param_count()
        c = self
        dense = dataclasses.replace(c, num_experts=0, top_k=0)
        full_moe_ff = 0
        active_ff = 0
        for i in range(c.n_layers):
            b = c.pattern[i % len(c.pattern)]
            if b.kind in ("attn", "local", "cross"):
                full_moe_ff += c.num_experts * 3 * c.d_model * c.d_ff
                active_ff += c.top_k * 3 * c.d_model * c.d_ff
        return dense.param_count() - (
            c.n_layers * 3 * c.d_model * c.d_ff
        ) + active_ff if False else (
            c.param_count() - full_moe_ff + active_ff
        )

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        pat = tuple(self.pattern)
        small = dict(
            n_layers=len(pat) + 1 if len(pat) > 1 else 2,
            d_model=64,
            n_heads=4,
            kv_heads=2 if self.kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            num_experts=4 if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            vision_dim=32 if self.vision_dim else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            frame_input_dim=24 if self.frame_input_dim else 0,
            lru_width=64 if self.lru_width else 0,
            d_inner=128 if self.d_inner else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=32 if self.d_inner else 64,
            ssm_chunk=16,
            pattern=tuple(
                dataclasses.replace(b, window=min(b.window, 8) if b.window else 0)
                for b in pat
            ),
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


_REGISTRY: dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    # import for registration side effects
    from repro.configs import (  # noqa: F401
        mixtral_8x22b,
        llama4_scout_17b_a16e,
        hubert_xlarge,
        llama_3_2_vision_90b,
        granite_8b,
        gemma3_27b,
        stablelm_1_6b,
        qwen2_72b,
        recurrentgemma_2b,
        mamba2_370m,
    )


def cell_status(arch: str, shape: str) -> str:
    """'ok' or 'skip:<reason>' for an (arch, shape) dry-run cell."""
    cfg = get_config(arch)
    _, _, kind = SHAPES[shape]
    if cfg.is_encoder and kind == "decode":
        return "skip:encoder-only (no decode step)"
    if shape == "long_500k" and not cfg.sub_quadratic:
        return "skip:pure full-attention (long_500k needs sub-quadratic)"
    return "ok"
