from repro.configs.base import (  # noqa: F401
    SHAPES,
    BlockSpec,
    ModelConfig,
    cell_status,
    get_config,
    list_archs,
    register,
)
