"""StableLM-2 1.6B [hf:stabilityai/stablelm-2-1_6b; unverified]: 24L d2048
32H (kv=32, MHA) ff5632 vocab 100352."""
from repro.configs.base import BlockSpec, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        kv_heads=32,
        head_dim=64,
        d_ff=5632,
        vocab=100352,
        pattern=(BlockSpec(kind="attn", window=0),),
        qkv_bias=True,
        rope_theta=10_000.0,
    )
)
