"""Mixtral 8x22B [arXiv:2401.04088; hf]: 56L d6144 48H GQA(kv=8) ff16384
vocab 32768, MoE 8 experts top-2, sliding-window attention."""
from repro.configs.base import BlockSpec, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=32768,
        pattern=(BlockSpec(kind="attn", window=4096),),  # SWA all layers
        num_experts=8,
        top_k=2,
        rope_theta=1_000_000.0,
    )
)
