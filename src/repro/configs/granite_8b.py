"""Granite-8B-Code [arXiv:2405.04324; hf]: 36L d4096 32H GQA(kv=8) ff14336
vocab 49152, llama-style dense decoder."""
from repro.configs.base import BlockSpec, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="granite-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=49152,
        pattern=(BlockSpec(kind="attn", window=0),),
        rope_theta=10_000_000.0,
    )
)
