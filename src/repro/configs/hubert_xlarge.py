"""HuBERT X-Large [arXiv:2106.07447; unverified]: 48L d1280 16H ff5120
vocab 504 (masked-unit targets). Encoder-only; the CNN waveform frontend is a
STUB — input_specs() provides precomputed frame embeddings (d=512)."""
from repro.configs.base import BlockSpec, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab=504,
        pattern=(BlockSpec(kind="attn", window=0),),
        causal=False,  # bidirectional encoder
        frame_input_dim=512,
        act="gelu",
        rope_theta=10_000.0,
    )
)
