"""Llama-4 Scout 17B-active 16E [hf:meta-llama/Llama-4-Scout-17B-16E;
unverified]: 48L d5120 40H GQA(kv=8) expert-ff 8192 vocab 202048,
MoE 16 experts top-1 (text backbone; early-fusion frontend stubbed)."""
from repro.configs.base import BlockSpec, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=202048,
        pattern=(BlockSpec(kind="attn", window=0),),  # full attention
        num_experts=16,
        top_k=1,
        rope_theta=500_000.0,
    )
)
