import jax

from repro.models import blocks, lm  # noqa: F401
from repro.models.template import (  # noqa: F401
    abstract_from_template,
    init_from_template,
    shardings_from_template,
    specs_from_template,
)


def init_params(cfg, key):
    return init_from_template(lm.model_template(cfg), key)


def abstract_params(cfg):
    return abstract_from_template(lm.model_template(cfg))


def init_cache(cfg, batch, max_seq, key=None):
    tmpl = lm.cache_template(cfg, batch, max_seq)
    return init_from_template(tmpl, key or jax.random.PRNGKey(0))


def abstract_cache(cfg, batch, max_seq):
    return abstract_from_template(lm.cache_template(cfg, batch, max_seq))
