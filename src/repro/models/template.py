"""Parameter templates: single source of truth for shapes, init, sharding.

A template is a pytree whose leaves are ParamSpec. From it we derive
  * real initialized params      (init_from_template)
  * ShapeDtypeStruct stand-ins   (abstract_from_template; for the dry-run)
  * NamedSharding trees          (shardings_from_template, under a Ruleset)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.dist import sharding as sh


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple  # logical axis names (len == ndim)
    dtype: jnp.dtype = jnp.float32
    init: str = "fan_in"  # fan_in | zeros | ones | normal | embed
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def stack_specs(tree, n: int, axis_name: str = "layers"):
    """Add a leading stacked-layers dim of size n to every leaf."""
    return jax.tree.map(
        lambda s: dataclasses.replace(
            s, shape=(n,) + s.shape, axes=(axis_name,) + s.axes
        ),
        tree,
        is_leaf=is_spec,
    )


def _init_leaf(spec: ParamSpec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "neg_ones":
        return -jnp.ones(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        return spec.scale * jax.random.normal(key, spec.shape, spec.dtype)
    if spec.init == "embed":
        return jax.random.normal(key, spec.shape, spec.dtype)
    if spec.init == "fan_in":
        # fan-in = second-to-last dim for matrices (ignoring stacked dims)
        fan = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / max(fan, 1) ** 0.5
        return std * jax.random.normal(key, spec.shape, spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_from_template(tree, key) -> dict:
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    paths = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_spec)[0]
    out = []
    for (path, spec) in paths:
        k = jax.random.fold_in(key, _path_hash(path))
        out.append(_init_leaf(spec, k))
    del leaves
    return jax.tree.unflatten(treedef, out)


def _path_hash(path) -> int:
    s = jax.tree_util.keystr(path)
    h = 2166136261
    for ch in s.encode():
        h = (h ^ ch) * 16777619 % (1 << 31)
    return h


def abstract_from_template(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        tree,
        is_leaf=is_spec,
    )


def specs_from_template(tree, ruleset: Optional[sh.Ruleset] = None):
    """PartitionSpec tree (requires an active or explicit Ruleset)."""
    rs = ruleset or sh.active()
    assert rs is not None, "specs_from_template needs a Ruleset"
    return jax.tree.map(
        lambda s: rs.spec(s.axes, s.shape), tree, is_leaf=is_spec
    )


def shardings_from_template(tree, ruleset: Optional[sh.Ruleset] = None):
    rs = ruleset or sh.active()
    assert rs is not None, "shardings_from_template needs a Ruleset"
    return jax.tree.map(
        lambda s: rs.sharding(s.axes, s.shape), tree, is_leaf=is_spec
    )


def nbytes(tree) -> int:
    return sum(
        int(jnp.dtype(s.dtype).itemsize) * _prod(s.shape)
        for s in jax.tree.leaves(tree, is_leaf=is_spec)
    )


def _prod(shape) -> int:
    out = 1
    for d in shape:
        out *= int(d)
    return out
