"""Transformer / SSM / recurrent building blocks (pure JAX, template-driven).

Every block has a ``*_template(cfg)`` returning a ParamSpec tree and a
forward taking ``(cfg, params, x, ...)``. Compute runs in bf16 with fp32
accumulation; params are fp32.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import axis_size, constrain, kv_repeat
from repro.models.template import ParamSpec

F32 = jnp.float32
DEFAULT_COMPUTE = jnp.bfloat16

ATTN_CHUNK = 1024  # kv-chunk for flash-style attention
MOE_GROUP = 1024  # tokens per MoE dispatch group

# When True, inner lax.scans (attention kv-chunks, SSD chunk recurrence) are
# unrolled into python loops so XLA cost_analysis counts every iteration.
# Used ONLY by the roofline calibration compiles (see launch/roofline.py).
INNER_UNROLL = False

# Route full-sequence self-attention through the Pallas flash-attention
# kernel (repro/kernels/flash_attention). interpret=True on CPU; on real
# TPU this is the production path that keeps score tiles in VMEM.
USE_PALLAS_ATTENTION = False
PALLAS_INTERPRET = True


def _maybe_unrolled_scan(step, init, xs, length):
    if not INNER_UNROLL:
        return lax.scan(step, init, xs)
    carry = init
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda t: t[i], xs)
        carry, y = step(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *t: jnp.stack(t), *ys)
    else:
        ys = None
    return carry, ys


def cast(x, dtype):
    return x.astype(dtype) if x.dtype != dtype else x


# ------------------------------------------------------------------ norms
def rms_norm(x, scale, eps):
    xf = cast(x, F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * (1.0 + cast(scale, F32))
    return cast(out, x.dtype)


def _act(name):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[name]


# ------------------------------------------------------------------ rope
def rope(x, positions, theta):
    """x: (..., S, H, dh), positions: (..., S) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -jnp.log(jnp.float32(theta)) * jnp.arange(half, dtype=F32) / half
    )
    ang = positions[..., None].astype(F32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(cast(x, F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return cast(out, x.dtype)


# ------------------------------------------------------------------ attention cores
# GQA grouping is KV-MAJOR everywhere: q head h uses kv head h // (H/KV), so
# consecutive q heads share a kv head. With q heads sharded over 'model',
# each shard's q group aligns exactly with its local kv shard — G-major
# grouping forced XLA to all-gather the whole KV cache per layer.
def _gqa_scores(q, k, scale):
    """q: (B,Sq,KV,G,dh) k: (B,Sk,KV,dh) -> (B,KV,G,Sq,Sk) fp32."""
    return jnp.einsum(
        "bqkgd,bskd->bkgqs", q, k, preferred_element_type=F32
    ) * scale


def direct_attention(q, k, v, mask, scale):
    """Reference full-materialisation attention.

    q: (B,Sq,H,dh); k,v: (B,Sk,KV,dh); mask broadcastable to (B,1,1,Sq,Sk)."""
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dh)
    s = _gqa_scores(qg, k, scale)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", cast(p, v.dtype), v, preferred_element_type=F32)
    return cast(o.reshape(B, Sq, H, dh), q.dtype)


def chunked_attention(q, k, v, *, causal, window, scale, chunk=ATTN_CHUNK):
    """Flash-style online-softmax attention, scanned over KV chunks.

    O(Sq * chunk) live memory; exact. q:(B,Sq,H,dh) k,v:(B,Sk,KV,dh).
    ``window``>0 restricts to a trailing sliding window (causal only).
    """
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    if Sk % chunk:
        chunk = Sk  # fallback: single chunk
    nck = Sk // chunk
    qg = q.reshape(B, Sq, KV, G, dh)
    kc = k.reshape(B, nck, chunk, KV, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nck, chunk, KV, dh).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(Sq, dtype=jnp.int32)

    def step(carry, xs):
        acc, m, l = carry
        kb, vb, ci = xs
        s = _gqa_scores(qg, kb, scale)  # (B,KV,G,Sq,chunk)
        k_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        valid = jnp.ones((Sq, chunk), bool)
        if causal:
            valid &= q_pos[:, None] >= k_pos[None, :]
        if window:
            valid &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(valid[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", cast(p, vb.dtype), vb, preferred_element_type=F32
        )
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, KV, G, Sq, dh), F32)
    m0 = jnp.full((B, KV, G, Sq), -jnp.inf, F32)
    l0 = jnp.zeros((B, KV, G, Sq), F32)
    (acc, m, l), _ = _maybe_unrolled_scan(
        step, (acc0, m0, l0), (kc, vc, jnp.arange(nck, dtype=jnp.int32)), nck
    )
    o = acc / jnp.maximum(l[..., None], 1e-30)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, dh)  # (B,Sq,KV,G,dh)->flat
    return cast(o, q.dtype)


def local_attention(q, k, v, *, window, scale):
    """Exact sliding-window attention via (self + previous) blocks.

    FLOPs O(S * 2*window) instead of O(S^2). Block rows are processed through
    a scan so only one row's (w, 2w) score tile is live at a time."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    w = window
    pad = (-S) % w
    if pad:
        padfn = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        q, k, v = padfn(q), padfn(k), padfn(v)
    Sp = S + pad
    nb = Sp // w
    qb = q.reshape(B, nb, w, KV, G, dh)
    kb = k.reshape(B, nb, w, KV, dh)
    vb = v.reshape(B, nb, w, KV, dh)
    shift = lambda t: jnp.pad(t, ((0, 0), (1, 0)) + ((0, 0),) * (t.ndim - 2))[:, :-1]
    kctx = jnp.concatenate([shift(kb), kb], axis=2)  # (B,nb,2w,KV,dh)
    vctx = jnp.concatenate([shift(vb), vb], axis=2)
    q_pos = jnp.arange(w)[:, None]  # within-block
    k_pos = jnp.arange(2 * w)[None, :] - w
    rel = q_pos - k_pos  # absolute distance q-k
    valid = (rel >= 0) & (rel < w)

    def row(_, xs):
        qi, ki, vi, is_first = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki,
                       preferred_element_type=F32) * scale
        v_ok = valid & ~(is_first & (k_pos < 0))
        s = jnp.where(v_ok[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", cast(p, vi.dtype), vi,
                       preferred_element_type=F32)
        return None, cast(o, qi.dtype)

    first = jnp.zeros((nb,), bool).at[0].set(True)
    xs = (qb.transpose(1, 0, 2, 3, 4, 5), kctx.transpose(1, 0, 2, 3, 4),
          vctx.transpose(1, 0, 2, 3, 4), first)
    _, ob = _maybe_unrolled_scan(row, None, xs, nb)
    o = ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sp, H, dh)
    return o[:, :S]


def decode_attention(q, ck, cv, cpos, pos, *, window, scale):
    """Single-token attention over a (ring-buffer) cache.

    q: (B,1,H,dh); ck/cv: (B,W,KV,dh); cpos: (W,) int32 absolute positions
    written (-1 = empty); pos: scalar current position."""
    B, _, H, dh = q.shape
    KV = ck.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, dh)
    s = _gqa_scores(qg, ck, scale)[..., 0, :]  # (B,KV,G,W)
    valid = (cpos >= 0) & (cpos <= pos)
    if window:
        valid &= pos - cpos < window
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", cast(p, cv.dtype), cv,
                   preferred_element_type=F32)
    return cast(o.reshape(B, 1, H, dh), q.dtype)


# ------------------------------------------------------------------ attention block
def attn_template(cfg, kind: str):
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    kv_in = cfg.vision_dim if kind == "cross" else D
    t = {
        "ln": ParamSpec((D,), ("embed",), init="zeros"),
        "wq": ParamSpec((D, H, dh), ("fsdp", "heads", None)),
        "wk": ParamSpec((kv_in, KV, dh), ("fsdp", "kv", None)),
        "wv": ParamSpec((kv_in, KV, dh), ("fsdp", "kv", None)),
        "wo": ParamSpec((H, dh, D), ("heads", None, "fsdp")),
    }
    if cfg.qkv_bias:
        t["bq"] = ParamSpec((H, dh), ("heads", None), init="zeros")
        t["bk"] = ParamSpec((KV, dh), ("kv", None), init="zeros")
        t["bv"] = ParamSpec((KV, dh), ("kv", None), init="zeros")
    if kind == "cross":
        t["gate"] = ParamSpec((), (), init="zeros")
    return t


def qkv_proj(cfg, p, x, cross_kv=None, dtype=DEFAULT_COMPUTE):
    """Returns q (B,S,H,dh), k, v (B,S,KVeff,dh) with kv repeated for TP."""
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"], dtype))
    kv_src = cast(cross_kv, dtype) if cross_kv is not None else x
    k = jnp.einsum("bsd,dhk->bshk", kv_src, cast(p["wk"], dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, cast(p["wv"], dtype))
    if cfg.qkv_bias:
        q = q + cast(p["bq"], dtype)
        k = k + cast(p["bk"], dtype)
        v = v + cast(p["bv"], dtype)
    r = kv_repeat(cfg.kv_heads, cfg.n_heads)
    if r > 1:
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
    q = constrain(q, ("batch", _q_seq_axis(cfg), "heads", None))
    k = constrain(k, ("batch", "seq", "kv", None))
    v = constrain(v, ("batch", "seq", "kv", None))
    return q, k, v


def _q_seq_axis(cfg) -> str:
    """Context-parallel attention fallback: if the head count can't shard
    over 'model', shard queries/outputs on their sequence dim instead."""
    m = axis_size("model")
    return "ctx_attn" if (m > 1 and cfg.n_heads % m) else "seq"


def attention_block(cfg, p, x, *, kind, window, positions, cross_kv=None,
                    dtype=DEFAULT_COMPUTE, return_cache=False, max_seq=None):
    """Full-sequence (train / prefill) attention sublayer. Returns residual
    delta (and, if return_cache, the decode cache this prefill produces)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = qkv_proj(cfg, p, h, cross_kv if kind == "cross" else None, dtype)
    scale = cfg.head_dim ** -0.5
    if kind != "cross":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    S = x.shape[1]
    if kind == "cross":
        o = chunked_attention(q, k, v, causal=False, window=0, scale=scale)
    elif USE_PALLAS_ATTENTION and kind != "cross":
        from repro.kernels.flash_attention import mha  # lazy: optional path
        o = mha(q, k, v, causal=cfg.causal, window=window,
                block_q=min(128, S), block_k=min(128, S),
                interpret=PALLAS_INTERPRET)
    elif window and S > window:
        o = local_attention(q, k, v, window=window, scale=scale)
    elif S > ATTN_CHUNK:
        o = chunked_attention(q, k, v, causal=cfg.causal, window=window, scale=scale)
    else:
        q_pos = jnp.arange(S)
        mask = jnp.ones((S, S), bool)
        if cfg.causal:
            mask &= q_pos[:, None] >= q_pos[None, :]
        if window:
            mask &= q_pos[:, None] - q_pos[None, :] < window
        o = direct_attention(q, k, v, mask[None, None, None], scale)
    o = constrain(o, ("batch", _q_seq_axis(cfg), "heads", None))
    out = jnp.einsum("bshk,hkd->bsd", o, cast(p["wo"], dtype))
    if kind == "cross":
        out = jnp.tanh(cast(p["gate"], F32)).astype(dtype) * out
    out = constrain(out, ("batch", "seq", "embed"))
    if not return_cache:
        return out
    if kind == "cross":
        cache = {"k": cast(k, jnp.bfloat16), "v": cast(v, jnp.bfloat16)}
    else:
        ms = max_seq or S
        W = min(window, ms) if window else ms
        keep = min(W, S)  # most recent tokens that fit the ring
        pos = jnp.arange(S - keep, S, dtype=jnp.int32)
        slots = pos % W
        int8 = cfg.kv_dtype == "int8"
        kv_dt = jnp.int8 if int8 else jnp.bfloat16
        if int8:
            kq, ksc = _kv_quant(k[:, S - keep:])
            vq, vsc = _kv_quant(v[:, S - keep:])
        else:
            kq, vq = cast(k[:, S - keep:], kv_dt), cast(v[:, S - keep:], kv_dt)
        ck = jnp.zeros((k.shape[0], W) + k.shape[2:], kv_dt)
        cv = jnp.zeros_like(ck)
        ck = ck.at[:, slots].set(kq)
        cv = cv.at[:, slots].set(vq)
        cpos = jnp.full((W,), -1, jnp.int32).at[slots].set(pos)
        cache = {"k": ck, "v": cv, "pos": cpos}
        if int8:
            zs = jnp.zeros((k.shape[0], W, k.shape[2], 1), F32)
            cache["k_scale"] = zs.at[:, slots].set(ksc)
            cache["v_scale"] = zs.at[:, slots].set(vsc)
    return out, cache


def _kv_quant(x):
    """Per-(token, head) symmetric int8 over head_dim. x: (..., dh)."""
    from repro.core.boundary import rowwise_quant  # lazy: avoid import cycle
    return rowwise_quant(x, 127)


def _kv_deq(q, s, dtype):
    return (q.astype(F32) * s).astype(dtype)


def attention_decode(cfg, p, x, cache, pos, *, kind, window, cross_kv=None,
                     dtype=DEFAULT_COMPUTE):
    """One-token attention with cache update. x: (B,1,D). Returns (delta,
    new_cache)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    scale = cfg.head_dim ** -0.5
    if kind == "cross":
        # static cross-kv: cache holds projected vision k/v, no update
        q = jnp.einsum("bsd,dhk->bshk", h, cast(p["wq"], dtype))
        r = kv_repeat(cfg.kv_heads, cfg.n_heads)
        ck, cv = cache["k"], cache["v"]
        W = ck.shape[1]
        o = decode_attention(q, ck, cv, jnp.zeros((W,), jnp.int32), pos,
                             window=0, scale=scale)
        out = jnp.einsum("bshk,hkd->bsd", o, cast(p["wo"], dtype))
        out = jnp.tanh(cast(p["gate"], F32)).astype(dtype) * out
        return out, cache
    q, k, v = qkv_proj(cfg, p, h, None, dtype)
    posv = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_theta)
    W = cache["k"].shape[1]
    slot = pos % W
    int8 = cfg.kv_dtype == "int8"
    if int8:
        kq, ks = _kv_quant(k)
        vq, vs = _kv_quant(v)
    else:
        kq, vq = cast(k, cache["k"].dtype), cast(v, cache["v"].dtype)
    ck = lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0))
    cpos = lax.dynamic_update_slice(cache["pos"], pos[None].astype(jnp.int32),
                                    (slot,))
    ck = constrain(ck, ("batch", "cache_seq", "kv", None))
    cv = constrain(cv, ("batch", "cache_seq", "kv", None))
    new_cache = {"k": ck, "v": cv, "pos": cpos}
    if int8:
        cks = lax.dynamic_update_slice(cache["k_scale"], ks, (0, slot, 0, 0))
        cvs = lax.dynamic_update_slice(cache["v_scale"], vs, (0, slot, 0, 0))
        new_cache["k_scale"], new_cache["v_scale"] = cks, cvs
        ck = _kv_deq(ck, cks, dtype)
        cv = _kv_deq(cv, cvs, dtype)
    o = decode_attention(q, ck, cv, cpos, pos, window=window, scale=scale)
    out = jnp.einsum("bshk,hkd->bsd", o, cast(p["wo"], dtype))
    return constrain(out, ("batch", "seq", "embed")), new_cache


def attn_cache_template(cfg, batch, max_seq, window, kind):
    r = kv_repeat(cfg.kv_heads, cfg.n_heads)
    kveff = cfg.kv_heads * r
    if kind == "cross":
        W = cfg.vision_tokens
        return {
            "k": ParamSpec((batch, W, kveff, cfg.head_dim),
                           ("batch", None, "kv", None), jnp.bfloat16, "zeros"),
            "v": ParamSpec((batch, W, kveff, cfg.head_dim),
                           ("batch", None, "kv", None), jnp.bfloat16, "zeros"),
        }
    W = min(window, max_seq) if window else max_seq
    int8 = cfg.kv_dtype == "int8"
    kv_dt = jnp.int8 if int8 else jnp.bfloat16
    t = {
        "k": ParamSpec((batch, W, kveff, cfg.head_dim),
                       ("batch", "cache_seq", "kv", None), kv_dt, "zeros"),
        "v": ParamSpec((batch, W, kveff, cfg.head_dim),
                       ("batch", "cache_seq", "kv", None), kv_dt, "zeros"),
        "pos": ParamSpec((W,), ("cache_seq",), jnp.int32, "neg_ones"),
    }
    if int8:
        t["k_scale"] = ParamSpec((batch, W, kveff, 1),
                                 ("batch", "cache_seq", "kv", None),
                                 F32, "zeros")
        t["v_scale"] = ParamSpec((batch, W, kveff, 1),
                                 ("batch", "cache_seq", "kv", None),
                                 F32, "zeros")
    return t


# ------------------------------------------------------------------ MLP
def mlp_template(cfg):
    D, Fd = cfg.d_model, cfg.d_ff
    return {
        "ln": ParamSpec((D,), ("embed",), init="zeros"),
        "wg": ParamSpec((D, Fd), ("fsdp", "ff")),
        "wu": ParamSpec((D, Fd), ("fsdp", "ff")),
        "wd": ParamSpec((Fd, D), ("ff", "fsdp")),
    }


def mlp_block(cfg, p, x, dtype=DEFAULT_COMPUTE):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    g = _act(cfg.act)(h @ cast(p["wg"], dtype))
    u = h @ cast(p["wu"], dtype)
    hid = constrain(g * u, ("batch", "seq", "ff"))
    return constrain(hid @ cast(p["wd"], dtype), ("batch", "seq", "embed"))


# ------------------------------------------------------------------ MoE
def moe_template(cfg):
    D, Fd, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "ln": ParamSpec((D,), ("embed",), init="zeros"),
        "router": ParamSpec((D, E), (None, None), init="fan_in"),
        "wg": ParamSpec((E, D, Fd), ("experts", "fsdp", "ff")),
        "wu": ParamSpec((E, D, Fd), ("experts", "fsdp", "ff")),
        "wd": ParamSpec((E, Fd, D), ("experts", "ff", "fsdp")),
    }


def moe_block(cfg, p, x, dtype=DEFAULT_COMPUTE):
    """Group-wise top-k dispatch/combine (Switch-style with capacity).

    Returns (delta, aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    T = B * S
    g = min(MOE_GROUP, T)
    if T % g:
        g = T
    nG = T // g
    xt = constrain(h.reshape(nG, g, D), ("batch", None, "embed"))
    logits = jnp.einsum("gtd,de->gte", cast(xt, F32), cast(p["router"], F32))
    probs = jax.nn.softmax(logits, axis=-1)  # (nG,g,E)
    top_p, top_i = lax.top_k(probs, K)  # (nG,g,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    cap = max(1, int(cfg.capacity_factor * g * K / E))
    onehot = jax.nn.one_hot(top_i, E, dtype=F32)  # (nG,g,K,E)
    # position of each (token,k) within its expert queue, priority by k then t
    flat = onehot.transpose(0, 2, 1, 3).reshape(nG, K * g, E)
    pos = jnp.cumsum(flat, axis=1) - flat  # (nG,K*g,E)
    pos = pos.reshape(nG, K, g, E).transpose(0, 2, 1, 3)  # (nG,g,K,E)
    keep = (pos < cap) * onehot
    slot_idx = jnp.sum(pos * onehot, -1).astype(jnp.int32)
    slot = jax.nn.one_hot(slot_idx, cap, dtype=F32)  # (nG,g,K,cap)
    dispatch = jnp.einsum("gtke,gtkc->gtec", keep, slot)  # (nG,g,E,cap)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", keep, slot, top_p)
    xe = jnp.einsum("gtec,gtd->gecd", cast(dispatch, dtype), cast(xt, dtype))
    xe = constrain(xe, ("batch", "experts", None, "embed"))
    gg = _act(cfg.act)(jnp.einsum("gecd,edf->gecf", xe, cast(p["wg"], dtype)))
    uu = jnp.einsum("gecd,edf->gecf", xe, cast(p["wu"], dtype))
    hid = constrain(gg * uu, ("batch", "experts", None, "ff"))
    ye = jnp.einsum("gecf,efd->gecd", hid, cast(p["wd"], dtype))
    # reduce-scatter the ff-contraction onto the capacity dim instead of
    # all-reducing the full (groups,E,cap,D) buffer
    ye = constrain(ye, ("batch", "experts", "cap", "embed"))
    y = jnp.einsum("gecd,gtec->gtd", ye, cast(combine, dtype))
    y = constrain(y, ("batch", None, "embed"))
    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))  # (E,)
    fe = onehot.sum(axis=2).mean(axis=(0, 1))  # fraction routed per expert
    aux = E * jnp.sum(me * fe) / K
    return constrain(y.reshape(B, S, D), ("batch", "seq", "embed")), aux


# ------------------------------------------------------------------ RG-LRU (Griffin)
def rec_template(cfg):
    D, W = cfg.d_model, cfg.lru_width
    cw = cfg.conv1d_width
    return {
        "ln": ParamSpec((D,), ("embed",), init="zeros"),
        "wx": ParamSpec((D, W), ("fsdp", "ff")),
        "wy": ParamSpec((D, W), ("fsdp", "ff")),
        "conv_w": ParamSpec((cw, W), (None, "ff"), init="fan_in"),
        "conv_b": ParamSpec((W,), ("ff",), init="zeros"),
        "wi": ParamSpec((W, W), ("fsdp", "ff")),
        "wa": ParamSpec((W, W), ("fsdp", "ff")),
        "lam": ParamSpec((W,), ("ff",), init="normal", scale=0.5),
        "wo": ParamSpec((W, D), ("ff", "fsdp")),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x:(B,S,W) w:(cw,W). state: (B,cw-1,W) or None.
    Returns (y, new_state)."""
    cw = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([cast(state, x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(cw))
    new_state = xp[:, -(cw - 1) :] if cw > 1 else None
    return y + b, new_state


_LRU_C = 8.0


def _rglru_gates(p, xc, dtype):
    i = jax.nn.sigmoid(xc @ cast(p["wi"], dtype))
    r = jax.nn.sigmoid(xc @ cast(p["wa"], dtype))
    log_a = -_LRU_C * jax.nn.softplus(cast(p["lam"], F32)) * cast(r, F32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * cast(i, F32) * cast(xc, F32)
    return a, gated  # fp32


def rec_block(cfg, p, x, dtype=DEFAULT_COMPUTE):
    """RG-LRU temporal-mixing sublayer (train/prefill, associative scan).
    Returns (residual delta, decode state)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    xb = h @ cast(p["wx"], dtype)
    yb = _act(cfg.act)(h @ cast(p["wy"], dtype))
    xc, conv_state = _causal_conv(
        xb, cast(p["conv_w"], dtype), cast(p["conv_b"], dtype)
    )
    xc = constrain(xc, ("batch", "seq", "ff"))
    a, gated = _rglru_gates(p, xc, dtype)

    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, hseq = lax.associative_scan(comb, (a, gated), axis=1)
    out = (cast(hseq, dtype) * yb) @ cast(p["wo"], dtype)
    state = {"h": hseq[:, -1], "conv": cast(conv_state, jnp.bfloat16)}
    return constrain(out, ("batch", "seq", "embed")), state


def rec_decode(cfg, p, x, cache, dtype=DEFAULT_COMPUTE):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    xb = h @ cast(p["wx"], dtype)
    yb = _act(cfg.act)(h @ cast(p["wy"], dtype))
    xc, conv_state = _causal_conv(xb, cast(p["conv_w"], dtype),
                                  cast(p["conv_b"], dtype), cache["conv"])
    a, gated = _rglru_gates(p, xc, dtype)
    hnew = a * cache["h"][:, None] + gated  # (B,1,W)
    out = (cast(hnew, dtype) * yb) @ cast(p["wo"], dtype)
    return out, {"h": hnew[:, 0], "conv": cast(conv_state, cache["conv"].dtype)}


def rec_cache_template(cfg, batch):
    W, cw = cfg.lru_width, cfg.conv1d_width
    return {
        "h": ParamSpec((batch, W), ("batch", "ff"), F32, "zeros"),
        "conv": ParamSpec((batch, cw - 1, W), ("batch", None, "ff"),
                          jnp.bfloat16, "zeros"),
    }


# ------------------------------------------------------------------ Mamba2 SSD
def ssd_template(cfg):
    D, Din, N, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.d_inner // cfg.ssm_headdim
    G = cfg.ssm_ngroups
    cw = cfg.conv1d_width
    return {
        "ln": ParamSpec((D,), ("embed",), init="zeros"),
        "wz": ParamSpec((D, Din), ("fsdp", "ff")),
        "wx": ParamSpec((D, Din), ("fsdp", "ff")),
        "wB": ParamSpec((D, G * N), ("fsdp", None)),
        "wC": ParamSpec((D, G * N), ("fsdp", None)),
        "wdt": ParamSpec((D, nh), ("fsdp", "heads")),
        "dt_bias": ParamSpec((nh,), ("heads",), init="zeros"),
        "A_log": ParamSpec((nh,), ("heads",), init="normal", scale=0.5),
        "Dskip": ParamSpec((nh,), ("heads",), init="ones"),
        "conv_x": ParamSpec((cw, Din), (None, "ff"), init="fan_in"),
        "conv_B": ParamSpec((cw, G * N), (None, None), init="fan_in"),
        "conv_C": ParamSpec((cw, G * N), (None, None), init="fan_in"),
        "norm": ParamSpec((Din,), ("ff",), init="zeros"),
        "wo": ParamSpec((Din, D), ("ff", "fsdp")),
    }


def _ssd_inputs(cfg, p, x, dtype, conv_state=None):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    z = h @ cast(p["wz"], dtype)
    xs = h @ cast(p["wx"], dtype)
    Bm = h @ cast(p["wB"], dtype)
    Cm = h @ cast(p["wC"], dtype)
    dt = jax.nn.softplus(
        cast(h @ cast(p["wdt"], dtype), F32) + cast(p["dt_bias"], F32)
    )  # (B,S,nh) fp32
    states = {}
    for name in ("x", "B", "C"):
        t = {"x": xs, "B": Bm, "C": Cm}[name]
        st_in = None if conv_state is None else conv_state[name]
        t, st = _causal_conv(t, cast(p["conv_" + name], dtype), 0.0, st_in)
        t = jax.nn.silu(t)
        if name == "x":
            xs = t
        elif name == "B":
            Bm = t
        else:
            Cm = t
        states[name] = st
    nh = cfg.d_inner // cfg.ssm_headdim
    Bsz, S = x.shape[0], x.shape[1]
    xh = xs.reshape(Bsz, S, nh, cfg.ssm_headdim)
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    Bg = Bm.reshape(Bsz, S, G, N)
    Cg = Cm.reshape(Bsz, S, G, N)
    A = -jnp.exp(cast(p["A_log"], F32))  # (nh,)
    return z, xh, Bg, Cg, dt, A, states


def ssd_block(cfg, p, x, dtype=DEFAULT_COMPUTE):
    """Chunked state-space-dual (Mamba2) mixer: quadratic within chunks,
    linear state recurrence across chunks. Returns (delta, decode cache)."""
    z, xh, Bg, Cg, dt, A, conv_states = _ssd_inputs(cfg, p, x, dtype)
    if USE_PALLAS_ATTENTION:  # kernelised mixer core (VMEM-resident state)
        from repro.kernels.ssd import ssd as ssd_kernel
        B_, S_ = x.shape[0], x.shape[1]
        yk, s_last = ssd_kernel(xh, dt, A, Bg, Cg,
                                chunk=min(cfg.ssm_chunk, S_),
                                interpret=PALLAS_INTERPRET)
        nh = cfg.d_inner // cfg.ssm_headdim
        y = cast(yk, F32) + cast(p["Dskip"], F32)[:, None] * cast(xh, F32)
        y = y.reshape(B_, S_, cfg.d_inner)
        y = rms_norm(cast(y, dtype) * jax.nn.silu(z), p["norm"], cfg.norm_eps)
        out = y @ cast(p["wo"], dtype)
        G = cfg.ssm_ngroups
        cache = {"s": s_last.reshape(B_, G, nh // G, cfg.ssm_headdim,
                                     cfg.ssm_state),
                 "conv": {k: cast(v, jnp.bfloat16)
                          for k, v in conv_states.items()}}
        return constrain(out, ("batch", "seq", "embed")), cache
    B, S, nh, hd = xh.shape
    G, N = Bg.shape[2], Bg.shape[3]
    L = min(cfg.ssm_chunk, S)
    if S % L:
        L = S
    nc = S // L
    hpg = nh // G  # heads per B/C group
    xc = xh.reshape(B, nc, L, nh, hd)
    Bc = Bg.reshape(B, nc, L, G, N)
    Cc = Cg.reshape(B, nc, L, G, N)
    dtc = dt.reshape(B, nc, L, nh)
    dA = dtc * A  # (B,nc,L,nh) log-decay per step
    lcum = jnp.cumsum(dA, axis=2)  # inclusive cumsum of log decay
    # --- within chunk (quadratic, attention-like) ---
    CB = jnp.einsum("bclgn,bcmgn->bcglm", Cc, Bc, preferred_element_type=F32)
    CB = CB.reshape(B, nc, G, 1, L, L)
    decay = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]  # l_t - l_s (t q, s k)
    decay = jnp.transpose(decay, (0, 1, 4, 2, 3))  # (B,nc,nh,L,L) [t,s]
    tri = jnp.tril(jnp.ones((L, L), bool))
    M = jnp.where(tri, jnp.exp(decay), 0.0)
    M = M.reshape(B, nc, G, hpg, L, L) * CB  # (B,nc,G,hpg,L,L)
    du = dtc[..., None] * cast(xc, F32)  # (B,nc,L,nh,hd)
    duh = du.reshape(B, nc, L, G, hpg, hd)
    y_intra = jnp.einsum("bcghts,bcsghd->bctghd", M, duh)
    # --- chunk states ---
    lend = lcum[:, :, -1:, :]  # (B,nc,1,nh)
    sdecay = jnp.exp(lend - lcum)  # decay from s to chunk end
    S_c = jnp.einsum("bcsgn,bcsghd->bcghdn", Bc,
                     duh * sdecay.reshape(B, nc, L, G, hpg)[..., None])
    # --- recurrence across chunks ---
    chunk_decay = jnp.exp(lend[:, :, 0])  # (B,nc,nh)

    def step(s_prev, xs_):
        sc, cd = xs_
        s_new = s_prev * cd.reshape(B, G, hpg)[..., None, None] + sc
        return s_new, s_prev

    s0 = jnp.zeros((B, G, hpg, hd, N), F32)
    s_last, s_prevs = _maybe_unrolled_scan(
        step, s0,
        (S_c.transpose(1, 0, 2, 3, 4, 5), chunk_decay.transpose(1, 0, 2)), nc
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4, 5)  # (B,nc,G,hpg,hd,N)
    qdecay = jnp.exp(lcum).reshape(B, nc, L, G, hpg)  # decay chunk-start -> t
    y_inter = jnp.einsum("bctgn,bcghdn->bctghd", Cc, s_prevs) * qdecay[..., None]
    y = (y_intra + y_inter).reshape(B, nc, L, nh, hd)
    y = y + cast(p["Dskip"], F32)[:, None] * cast(xc, F32)
    y = y.reshape(B, S, nh * hd)
    y = rms_norm(cast(y, dtype) * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ cast(p["wo"], dtype)
    cache = {"s": s_last,
             "conv": {k: cast(v, jnp.bfloat16) for k, v in conv_states.items()}}
    return constrain(out, ("batch", "seq", "embed")), cache


def ssd_decode(cfg, p, x, cache, dtype=DEFAULT_COMPUTE):
    """Single-step SSD recurrence. cache: {'s': (B,G,hpg,hd,N), 'conv':...}"""
    conv_state = cache["conv"]
    z, xh, Bg, Cg, dt, A, new_conv = _ssd_inputs(cfg, p, x, dtype, conv_state)
    B = x.shape[0]
    nh, hd = xh.shape[2], xh.shape[3]
    G, N = Bg.shape[2], Bg.shape[3]
    hpg = nh // G
    dA = jnp.exp(dt[:, 0] * A)  # (B,nh)
    du = dt[:, 0, :, None] * cast(xh[:, 0], F32)  # (B,nh,hd)
    duh = du.reshape(B, G, hpg, hd)
    s = cache["s"] * dA.reshape(B, G, hpg)[..., None, None] + jnp.einsum(
        "bgn,bghd->bghdn", Bg[:, 0], duh
    )
    y = jnp.einsum("bgn,bghdn->bghd", Cg[:, 0], s)
    y = y + cast(p["Dskip"], F32).reshape(G, hpg)[None, ..., None] * cast(
        xh[:, 0].reshape(B, G, hpg, hd), F32
    )
    y = y.reshape(B, 1, nh * hd)
    y = rms_norm(cast(y, dtype) * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ cast(p["wo"], dtype)
    return out, {"s": s, "conv": new_conv}


def ssd_cache_template(cfg, batch):
    nh = cfg.d_inner // cfg.ssm_headdim
    G, N, hd = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim
    cw = cfg.conv1d_width
    return {
        "s": ParamSpec((batch, G, nh // G, hd, N),
                       ("batch", None, "heads", None, None), F32, "zeros"),
        "conv": {
            "x": ParamSpec((batch, cw - 1, cfg.d_inner), ("batch", None, "ff"),
                           jnp.bfloat16, "zeros"),
            "B": ParamSpec((batch, cw - 1, G * N), ("batch", None, None),
                           jnp.bfloat16, "zeros"),
            "C": ParamSpec((batch, cw - 1, G * N), ("batch", None, None),
                           jnp.bfloat16, "zeros"),
        },
    }
