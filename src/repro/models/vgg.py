"""VGG16 with the paper's 43 split points (outputs after every layer or
sub-layer: conv, ReLU, pool, avgpool, flatten, fc, dropout, softmax).

Used for the faithful reproduction of Figs. 5/6 and the dcor privacy
profile. A width/image-reduced variant runs on CPU for measured-dcor tests;
the analytic FLOPs/data-size profile always uses the configured geometry.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.profiles import SplitProfile
from repro.models.template import ParamSpec, init_from_template

F32 = jnp.float32

# (kind, arg): conv -> out_channels, pool -> window, fc -> out_features
_FEATURES = [
    ("conv", 64), ("relu", 0), ("conv", 64), ("relu", 0), ("pool", 2),
    ("conv", 128), ("relu", 0), ("conv", 128), ("relu", 0), ("pool", 2),
    ("conv", 256), ("relu", 0), ("conv", 256), ("relu", 0), ("conv", 256),
    ("relu", 0), ("pool", 2),
    ("conv", 512), ("relu", 0), ("conv", 512), ("relu", 0), ("conv", 512),
    ("relu", 0), ("pool", 2),
    ("conv", 512), ("relu", 0), ("conv", 512), ("relu", 0), ("conv", 512),
    ("relu", 0), ("pool", 2),
]


def layout(num_classes: int = 1000):
    ops = [("input", 0)] + list(_FEATURES)
    ops += [("avgpool", 7), ("flatten", 0)]
    ops += [("fc", 4096), ("relu", 0), ("dropout", 0),
            ("fc", 4096), ("relu", 0), ("dropout", 0),
            ("fc", num_classes), ("softmax", 0), ("output", 0)]
    assert len(ops) == 43, len(ops)
    return ops


@dataclasses.dataclass(frozen=True)
class VGGConfig:
    image_size: int = 224
    width_mult: float = 1.0
    num_classes: int = 1000
    in_channels: int = 3

    def ch(self, c: int) -> int:
        return max(4, int(c * self.width_mult))

    def fc_width(self, w: int) -> int:
        return max(16, int(w * self.width_mult))


FULL = VGGConfig()
REDUCED = VGGConfig(image_size=32, width_mult=0.125, num_classes=10)


def _shapes(vcfg: VGGConfig):
    """Activation shape (H, W, C) or (F,) after every split point."""
    ops = layout(vcfg.num_classes)
    h = w = vcfg.image_size
    c = vcfg.in_channels
    flat = None
    out = []
    for kind, arg in ops:
        if kind == "conv":
            c = vcfg.ch(arg)
        elif kind == "pool":
            h //= arg
            w //= arg
        elif kind == "avgpool":
            h = w = min(h, arg)
        elif kind == "flatten":
            flat = h * w * c
        elif kind == "fc":
            flat = (vcfg.fc_width(arg) if arg != vcfg.num_classes
                    else vcfg.num_classes)
        out.append((flat,) if flat is not None else (h, w, c))
    return out


def vgg_template(vcfg: VGGConfig):
    ops = layout(vcfg.num_classes)
    shapes = _shapes(vcfg)
    t = {}
    c_in = vcfg.in_channels
    flat_in = None
    for i, (kind, arg) in enumerate(ops):
        if kind == "conv":
            c_out = vcfg.ch(arg)
            t[f"op{i}_w"] = ParamSpec((3, 3, c_in, c_out),
                                      (None, None, None, None))
            t[f"op{i}_b"] = ParamSpec((c_out,), (None,), init="zeros")
            c_in = c_out
        elif kind == "flatten":
            sh = shapes[i - 1]
            flat_in = sh[0] * sh[1] * sh[2]
        elif kind == "fc":
            f_out = shapes[i][0]
            t[f"op{i}_w"] = ParamSpec((flat_in, f_out), (None, None))
            t[f"op{i}_b"] = ParamSpec((f_out,), (None,), init="zeros")
            flat_in = f_out
    return t


def init_vgg(vcfg: VGGConfig, key):
    return init_from_template(vgg_template(vcfg), key)


def forward(vcfg: VGGConfig, params, x, *, start: int = 0, stop: int = 43,
            collect: bool = False):
    """Run ops [start, stop). x: (N,H,W,C) images (or the split activation).
    Returns final activation, or the list of activations per op if collect."""
    ops = layout(vcfg.num_classes)
    acts = []
    for i in range(start, stop):
        kind, arg = ops[i]
        if kind in ("input", "output", "dropout"):  # identity at inference
            pass
        elif kind == "conv":
            x = jax.lax.conv_general_dilated(
                x, params[f"op{i}_w"], window_strides=(1, 1), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = x + params[f"op{i}_b"]
        elif kind == "relu":
            x = jax.nn.relu(x)
        elif kind == "pool":
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, arg, arg, 1), (1, arg, arg, 1),
                                      "VALID")
        elif kind == "avgpool":
            # adaptive to (arg, arg): here shapes already match or reduce
            h = x.shape[1]
            if h > arg:
                k = h // arg
                x = jax.lax.reduce_window(x, 0.0, jax.lax.add,
                                          (1, k, k, 1), (1, k, k, 1),
                                          "VALID") / (k * k)
        elif kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif kind == "fc":
            x = x @ params[f"op{i}_w"] + params[f"op{i}_b"]
        elif kind == "softmax":
            x = jax.nn.softmax(x, axis=-1)
        else:
            raise ValueError(kind)
        if collect:
            acts.append(x)
    return acts if collect else x


def vgg_split_profile(vcfg: VGGConfig, *, bytes_per_el: int = 4,
                      privacy: np.ndarray | None = None) -> SplitProfile:
    """Analytic per-split profile (FLOPs cumulative, bytes transmitted)."""
    ops = layout(vcfg.num_classes)
    shapes = _shapes(vcfg)
    flops = []
    c_in = vcfg.in_channels
    flat_in = None
    for i, (kind, arg) in enumerate(ops):
        sh = shapes[i]
        if kind == "conv":
            h, w, c = sh
            flops.append(2 * 9 * c_in * c * h * w)
            c_in = c
        elif kind in ("relu", "pool", "avgpool", "softmax"):
            flops.append(float(np.prod(sh)))
        elif kind == "fc":
            flops.append(2 * flat_in * sh[0])
            flat_in = sh[0]
        elif kind == "flatten":
            flat_in = int(np.prod(shapes[i - 1]))
            flops.append(0.0)
        else:
            flops.append(0.0)
    data = np.array([float(np.prod(s)) * bytes_per_el for s in shapes])
    if privacy is None:
        privacy = paper_privacy_profile()
    return SplitProfile(name=f"vgg16-{vcfg.image_size}px-w{vcfg.width_mult}",
                        flops_head=np.cumsum(flops).astype(float),
                        data_bytes=data, privacy=np.asarray(privacy, float),
                        layer_names=[f"{i+1}:{k}" for i, (k, _) in
                                     enumerate(ops)])


def paper_privacy_profile() -> np.ndarray:
    """dCor(input, act_l) for VGG16 calibrated to the paper's Fig. 5b:
    highest near the input, gradual decay, sharp decline around split 25,
    minima ~0.21-0.22 at splits 25, 38, 43 (1-indexed). Split 1 is the raw
    input (dCor exactly 1.0): a privacy-focused SC system never ships it, so
    any rho_max < 1 prefilters it in Algorithm 1."""
    l = np.arange(1, 44)
    base = 0.97 - 0.45 * (l / 43) ** 1.5
    drop = 0.30 / (1.0 + np.exp(-(l - 24.5) * 1.5))
    p = base - drop
    p = np.clip(p, 0.2, 1.0)
    p[0] = 1.0  # op 'input': untouched image
    p[24] = 0.215  # split 25
    p[37] = 0.220  # split 38
    p[42] = 0.210  # split 43
    return p
