"""Pattern-grouped LM: scan over repeated megablocks + unrolled remainder.

Covers every assigned architecture through ModelConfig.pattern:
dense / MoE / SWA / local:global / cross-attn VLM / RG-LRU hybrid / SSD.

Modes: train & prefill are full-sequence; decode is single-token with a
cache pytree that mirrors the parameter grouping.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import constrain
from repro.models import blocks as B
from repro.models.template import ParamSpec, stack_specs

F32 = jnp.float32


# ------------------------------------------------------------------ templates
def block_template(cfg, spec):
    if spec.kind in ("attn", "local", "cross"):
        t = {"attn": B.attn_template(cfg, spec.kind)}
        t["ffn"] = B.moe_template(cfg) if cfg.is_moe else B.mlp_template(cfg)
        return t
    if spec.kind == "rec":
        return {"rec": B.rec_template(cfg), "ffn": B.mlp_template(cfg)}
    if spec.kind == "ssd":
        return {"ssd": B.ssd_template(cfg)}
    raise ValueError(spec.kind)


def model_template(cfg):
    D = cfg.d_model
    t = {}
    if cfg.frame_input_dim:
        t["embed"] = ParamSpec((cfg.frame_input_dim, D), ("fsdp", "embed"))
    else:
        t["embed"] = ParamSpec((cfg.vocab, D), ("vocab", "fsdp"), init="embed")
    n_full = cfg.n_full_patterns
    t["groups"] = tuple(
        stack_specs(block_template(cfg, b), n_full) for b in cfg.pattern
    )
    t["rem"] = tuple(block_template(cfg, b) for b in cfg.remainder)
    t["final_ln"] = ParamSpec((D,), ("embed",), init="zeros")
    if not cfg.tie_embeddings and not cfg.frame_input_dim:
        t["head"] = ParamSpec((D, cfg.vocab), ("fsdp", "vocab"))
    elif cfg.frame_input_dim:
        t["head"] = ParamSpec((D, cfg.vocab), ("fsdp", "vocab"))
    return t


def block_cache_template(cfg, spec, batch, max_seq):
    if spec.kind in ("attn", "local", "cross"):
        return {"attn": B.attn_cache_template(cfg, batch, max_seq, spec.window,
                                              spec.kind)}
    if spec.kind == "rec":
        return {"rec": B.rec_cache_template(cfg, batch)}
    if spec.kind == "ssd":
        return {"ssd": B.ssd_cache_template(cfg, batch)}
    raise ValueError(spec.kind)


def cache_template(cfg, batch, max_seq):
    n_full = cfg.n_full_patterns
    return {
        "groups": tuple(
            stack_specs(block_cache_template(cfg, b, batch, max_seq), n_full)
            for b in cfg.pattern
        ),
        "rem": tuple(
            block_cache_template(cfg, b, batch, max_seq) for b in cfg.remainder
        ),
    }


# ------------------------------------------------------------------ block apply
def _res(x):
    """Residual-stream carry constraint: logical 'ctx' maps to None by
    default; overriding ctx->'model' turns on sequence parallelism for the
    inter-block activations (Megatron-SP style gather/reduce-scatter)."""
    return constrain(x, ("batch", "ctx", "embed"))


def _apply_ffn(cfg, p, x, dtype):
    """Returns (x, aux)."""
    if cfg.is_moe:
        delta, aux = B.moe_block(cfg, p["ffn"], x, dtype)
        return _res(x + delta), aux
    return _res(x + B.mlp_block(cfg, p["ffn"], x, dtype)), 0.0


def apply_block(cfg, spec, p, x, *, mode, cache=None, pos=None, positions=None,
                vision=None, dtype=jnp.bfloat16, max_seq=None):
    """Apply one block. Returns (x, aux, new_cache)."""
    aux = 0.0
    if spec.kind in ("attn", "local", "cross"):
        kw = dict(kind=spec.kind, window=spec.window, dtype=dtype)
        if mode == "decode":
            delta, new_cache = B.attention_decode(cfg, p["attn"], x,
                                                  cache["attn"], pos, **kw)
        elif mode == "prefill":
            delta, new_cache = B.attention_block(
                cfg, p["attn"], x, positions=positions, cross_kv=vision,
                return_cache=True, max_seq=max_seq, **kw)
            new_cache = {"attn": new_cache}
        else:
            delta = B.attention_block(cfg, p["attn"], x, positions=positions,
                                      cross_kv=vision, **kw)
            new_cache = None
        if mode == "decode":
            new_cache = {"attn": new_cache}
        x = _res(x + delta)
        x, aux = _apply_ffn(cfg, p, x, dtype)
    elif spec.kind == "rec":
        if mode == "decode":
            delta, st = B.rec_decode(cfg, p["rec"], x, cache["rec"], dtype)
        else:
            delta, st = B.rec_block(cfg, p["rec"], x, dtype)
        x = _res(x + delta)
        x, aux = _apply_ffn(cfg, p, x, dtype)
        new_cache = {"rec": st} if mode != "train" else None
    elif spec.kind == "ssd":
        if mode == "decode":
            delta, st = B.ssd_decode(cfg, p["ssd"], x, cache["ssd"], dtype)
        else:
            delta, st = B.ssd_block(cfg, p["ssd"], x, dtype)
        x = _res(x + delta)
        new_cache = {"ssd": st} if mode != "train" else None
    else:
        raise ValueError(spec.kind)
    return x, aux, new_cache


# ------------------------------------------------------------------ embeddings
def embed_in(cfg, params, batch, dtype):
    if cfg.frame_input_dim:
        x = B.cast(batch["frames"], dtype) @ B.cast(params["embed"], dtype)
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dtype)
        if cfg.tie_embeddings:
            x = x * jnp.asarray(cfg.d_model**0.5, dtype)
    return constrain(x, ("batch", "seq", "embed"))


def logits_out(cfg, params, x, dtype):
    h = B.rms_norm(x, params["final_ln"], cfg.norm_eps)
    if "head" in params:
        logits = h @ B.cast(params["head"], dtype)
    else:
        logits = jnp.einsum("bsd,vd->bsv", h, B.cast(params["embed"], dtype))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(B.cast(logits, F32) / c)
    return constrain(logits, ("batch", "seq", "vocab"))


# ------------------------------------------------------------------ forward
def forward(cfg, params, batch, *, mode="train", dtype=jnp.bfloat16,
            remat="full", logits_mode="all", max_seq=None, unroll=False):
    """Full-sequence pass.

    mode: 'train' | 'prefill'. logits_mode: 'all' | 'last' | 'none'.
    max_seq sizes the decode cache a prefill produces.
    Returns (logits, aux, cache) — cache is None for train."""
    x = embed_in(cfg, params, batch, dtype)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    vision = batch.get("vision")

    def group_body(carry, gparams):
        x, aux = carry
        caches = []
        for i, spec in enumerate(cfg.pattern):
            x, a, c = apply_block(cfg, spec, gparams[i], x, mode=mode,
                                  positions=positions, vision=vision,
                                  dtype=dtype, max_seq=max_seq)
            aux = aux + jnp.asarray(a, F32)
            caches.append(c)
        out = tuple(caches) if mode == "prefill" else None
        return (x, aux), out

    body = group_body
    if remat == "full":
        body = jax.checkpoint(group_body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            group_body, prevent_cse=False,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    if unroll:
        carry = (x, jnp.zeros((), F32))
        ys = []
        for i in range(cfg.n_full_patterns):
            gp = jax.tree.map(lambda t: t[i], params["groups"])
            carry, y = body(carry, gp)
            ys.append(y)
        (x, aux) = carry
        group_caches = (jax.tree.map(lambda *t: jnp.stack(t), *ys)
                        if ys and ys[0] is not None else None)
    else:
        (x, aux), group_caches = lax.scan(body, (x, jnp.zeros((), F32)),
                                          params["groups"])
    rem_caches = []
    for spec, p in zip(cfg.remainder, params["rem"]):
        x, a, c = apply_block(cfg, spec, p, x, mode=mode, positions=positions,
                              vision=vision, dtype=dtype, max_seq=max_seq)
        aux = aux + a
        rem_caches.append(c)

    cache = None
    if mode == "prefill":
        cache = {"groups": group_caches, "rem": tuple(rem_caches)}
    if logits_mode == "none":
        return None, aux, cache
    if logits_mode == "last":
        x = x[:, -1:]
    logits = logits_out(cfg, params, x, dtype)
    return logits, aux, cache


def decode_step(cfg, params, cache, tokens, pos, *, dtype=jnp.bfloat16,
                unroll=False):
    """One decode step. tokens: (B,1) int32 (or frames); pos: scalar int32.
    Returns (logits (B,1,V), new_cache).

    The stacked cache rides in the scan CARRY and is updated with
    dynamic_update_index in place — carrying it as xs->ys would double-buffer
    the entire KV cache."""
    x = embed_in(cfg, params, {"tokens": tokens}, dtype)

    def layer_at(gcaches, idx):
        return jax.tree.map(
            lambda t: lax.dynamic_index_in_dim(t, idx, 0, keepdims=False),
            gcaches)

    def write_at(gcaches, idx, new):
        return jax.tree.map(
            lambda full, n: lax.dynamic_update_index_in_dim(
                full, n.astype(full.dtype), idx, 0), gcaches, new)

    def group_body(carry, xs):
        x, gcaches = carry
        gparams, idx = xs
        gcaches = list(gcaches)
        for i, spec in enumerate(cfg.pattern):
            x, _, c = apply_block(cfg, spec, gparams[i], x, mode="decode",
                                  cache=layer_at(gcaches[i], idx), pos=pos,
                                  dtype=dtype)
            gcaches[i] = write_at(gcaches[i], idx, c)
        return (x, tuple(gcaches)), None

    xs = (params["groups"], jnp.arange(cfg.n_full_patterns, dtype=jnp.int32))
    if unroll:
        carry = (x, cache["groups"])
        for i in range(cfg.n_full_patterns):
            carry, _ = group_body(carry, jax.tree.map(lambda t: t[i], xs))
        x, group_caches = carry
    else:
        (x, group_caches), _ = lax.scan(group_body, (x, cache["groups"]), xs)
    rem_caches = []
    for spec, p, c in zip(cfg.remainder, params["rem"], cache["rem"]):
        x, _, nc = apply_block(cfg, spec, p, x, mode="decode", cache=c,
                               pos=pos, dtype=dtype)
        rem_caches.append(nc)
    logits = logits_out(cfg, params, x, dtype)
    return logits, {"groups": group_caches, "rem": tuple(rem_caches)}


# ------------------------------------------------------------------ loss
def lm_loss(cfg, params, batch, *, dtype=jnp.bfloat16, remat="full",
            aux_weight=0.01, unroll=False):
    logits, aux, _ = forward(cfg, params, batch, mode="train", dtype=dtype,
                             remat=remat, unroll=unroll)
    labels = batch["labels"]
    V = logits.shape[-1]
    lse = jax.nn.logsumexp(logits.astype(F32), axis=-1)
    onehot = jax.nn.one_hot(labels, V, dtype=logits.dtype)
    picked = jnp.einsum("bsv,bsv->bs", onehot, logits).astype(F32)
    nll = lse - picked
    mask = batch.get("mask")
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = nll.size
    loss = nll.sum() / denom
    if cfg.is_moe:
        loss = loss + aux_weight * aux / max(cfg.n_layers, 1)
    return loss, {"nll": loss, "aux": aux}
