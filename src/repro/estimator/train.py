"""Training loop + metrics for the throughput estimator."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.estimator.model import (EstimatorConfig, estimator_forward,
                                   init_estimator)
from repro.optim import AdamW

F32 = jnp.float32


def r2_rmse(pred: np.ndarray, y: np.ndarray) -> tuple[float, float]:
    pred, y = np.asarray(pred, float), np.asarray(y, float)
    ss_res = float(np.sum((pred - y) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2)) or 1e-9
    return 1.0 - ss_res / ss_tot, float(np.sqrt(np.mean((pred - y) ** 2)))


def make_train_step(e: EstimatorConfig, opt: AdamW):
    @jax.jit
    def step(params, opt_state, batch, key):
        def loss_fn(p):
            pred = estimator_forward(e, p, batch["kpms"], batch["iq"],
                                     batch["alloc"], train=True, key=key)
            return jnp.mean((pred - batch["tp"]) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step


def train_estimator(e: EstimatorConfig, data: dict, *, steps: int = 300,
                    batch: int = 32, lr: float = 1e-3, seed: int = 0,
                    log_every: int = 50, eval_data: dict | None = None):
    key = jax.random.PRNGKey(seed)
    params = init_estimator(e, key)
    opt = AdamW(lr=lr, weight_decay=1e-4, clip_norm=1.0)
    opt_state = opt.init(params)
    step_fn = make_train_step(e, opt)
    n = len(data["tp"])
    rng = np.random.default_rng(seed)
    history = []
    for i in range(steps):
        idx = rng.integers(0, n, batch)
        mb = {k: jnp.asarray(v[idx]) for k, v in data.items()
              if k in ("kpms", "iq", "alloc", "tp")}
        key, sub = jax.random.split(key)
        params, opt_state, loss = step_fn(params, opt_state, mb, sub)
        if i % log_every == 0 or i == steps - 1:
            history.append((i, float(loss)))
    metrics = None
    if eval_data is not None:
        pred = predict(e, params, eval_data)
        metrics = r2_rmse(pred, eval_data["tp"])
    return params, history, metrics


@partial(jax.jit, static_argnums=0)
def _fwd(e, params, kpms, iq, alloc):
    return estimator_forward(e, params, kpms, iq, alloc)


def predict(e: EstimatorConfig, params, data: dict,
            batch: int | None = 64) -> np.ndarray:
    """Predicted throughput (Mbps) for every row of ``data``.

    ``batch=None`` runs the whole input through one forward pass — the
    fleet engine's per-report-period path (one ``predict`` per 0.1 s tick
    for all N UEs); an int chunks the input to bound peak memory."""
    outs = []
    n = len(data["tp"])
    batch = max(n, 1) if batch is None else batch
    for i in range(0, n, batch):
        outs.append(np.asarray(_fwd(
            e, params, jnp.asarray(data["kpms"][i:i + batch]),
            jnp.asarray(data["iq"][i:i + batch]),
            jnp.asarray(data["alloc"][i:i + batch]))))
    return np.concatenate(outs)
