"""Training loop + metrics for the throughput estimator.

Both training paths — the offline loop here and the online continual-
learning trainer (``repro.sim.online``) — share one jitted step factory:
:func:`make_indexed_step` keeps the full dataset (or replay buffer)
device-resident and gathers each minibatch by index *inside* the compiled
step, so the only per-step host->device traffic is a tiny ``(batch,)``
index vector instead of the minibatch tensors themselves. The factory
optionally traces under a ``dist.sharding`` deployment, which is how the
online trainer gets its data-sharded batch / replicated params / psum'd
grads for free.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding as sh
from repro.estimator.model import (EstimatorConfig, estimator_forward,
                                   init_estimator)
from repro.estimator.ssm import (SSMConfig, init_ssm, ssm_forward_seq,
                                 ssm_step)
from repro.optim import AdamW

F32 = jnp.float32

# the four fields every estimator batch carries (gen_dataset also emits
# "scenario", which is metadata, not a model input)
BATCH_KEYS = ("kpms", "iq", "alloc", "tp")

# the recurrent estimator's replay rows: the pre-report state, the report
# features, and the label (truncated-BPTT-1 — the stored state is a
# constant, gradients flow through the one stored step)
SSM_BATCH_KEYS = ("state", "feats", "tp")


def r2_rmse(pred: np.ndarray, y: np.ndarray) -> tuple[float, float]:
    pred, y = np.asarray(pred, float), np.asarray(y, float)
    ss_res = float(np.sum((pred - y) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2)) or 1e-9
    return 1.0 - ss_res / ss_tot, float(np.sqrt(np.mean((pred - y) ** 2)))


def estimator_loss(e: EstimatorConfig, params, batch, key=None):
    """MSE (Mbps^2) of the training-mode forward on one minibatch."""
    pred = estimator_forward(e, params, batch["kpms"], batch["iq"],
                             batch["alloc"], train=True, key=key)
    return jnp.mean((pred - batch["tp"]) ** 2)


def make_train_step(e: EstimatorConfig, opt: AdamW):
    """Explicit-minibatch AdamW step: the host hands the batch in.

    Kept as the reference semantics for :func:`make_indexed_step` (same
    loss, same update — the indexed path only moves the gather on-device);
    ``tests/test_channel_estimator.py`` pins their loss trajectories equal.
    """
    @jax.jit
    def step(params, opt_state, batch, key):
        loss, grads = jax.value_and_grad(
            lambda p: estimator_loss(e, p, batch, key))(params)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step


def make_indexed_step(e: EstimatorConfig, opt: AdamW, *, mesh=None,
                      overrides=None):
    """The shared offline/online step factory: gather-by-index inside jit.

    Returns ``step(params, opt_state, data, idx, key) -> (params,
    opt_state, loss)`` where ``data`` is the device-resident dataset (or
    replay buffer contents) keyed by :data:`BATCH_KEYS` and ``idx`` a
    ``(batch,)`` int32 row selection. The minibatch gather runs inside the
    compiled program, so one step costs an index transfer, not a minibatch
    copy — the fix for the offline loop's per-step host->device transfer.

    ``mesh``/``overrides``: an optional ``dist.sharding`` deployment
    entered inside the traced function (the online trainer's setting): the
    gathered batch shards over the mesh's data axis through the
    estimator's ``batch`` constrains, params stay replicated, and GSPMD
    inserts the gradient all-reduce (psum) automatically — the sharded and
    unsharded steps are numerically interchangeable (pinned allclose by
    ``tests/test_sim_online.py``).

    A ``data`` field may also be a ``(q, scales)`` tuple — the int8
    replay ring (``sim.online.ReplayBufferQ``): the gather then pulls the
    int8 rows plus their rowwise scales and dequantizes only the selected
    minibatch, inside the same compiled step.
    """
    def _gather(v, idx):
        if isinstance(v, tuple):  # int8 ring: (q, per-row scales)
            q, s = v
            sb = jnp.take(s, idx, axis=0)
            return (jnp.take(q, idx, axis=0).astype(F32)
                    * sb.reshape(sb.shape[0], *([1] * (q.ndim - 1))))
        return jnp.take(v, idx, axis=0)

    def _step(params, opt_state, data, idx, key):
        batch = {k: _gather(data[k], idx) for k in BATCH_KEYS}
        loss, grads = jax.value_and_grad(
            lambda p: estimator_loss(e, p, batch, key))(params)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    if mesh is None:
        return jax.jit(_step)
    ov = dict(overrides or {})

    @jax.jit
    def sharded_step(params, opt_state, data, idx, key):
        with sh.use_rules(mesh, ov):
            return _step(params, opt_state, data, idx, key)

    return sharded_step


def train_estimator(e: EstimatorConfig, data: dict, *, steps: int = 300,
                    batch: int = 32, lr: float = 1e-3, seed: int = 0,
                    log_every: int = 50, eval_data: dict | None = None):
    key = jax.random.PRNGKey(seed)
    params = init_estimator(e, key)
    opt = AdamW(lr=lr, weight_decay=1e-4, clip_norm=1.0)
    opt_state = opt.init(params)
    step_fn = make_indexed_step(e, opt)
    n = len(data["tp"])
    rng = np.random.default_rng(seed)
    # the dataset goes to device ONCE; each step ships only the (batch,)
    # index vector and gathers its minibatch inside the compiled step
    data_dev = {k: jnp.asarray(data[k]) for k in BATCH_KEYS}
    history = []
    for i in range(steps):
        idx = rng.integers(0, n, batch)
        key, sub = jax.random.split(key)
        params, opt_state, loss = step_fn(params, opt_state, data_dev,
                                          jnp.asarray(idx, jnp.int32), sub)
        if i % log_every == 0 or i == steps - 1:
            history.append((i, float(loss)))
    metrics = None
    if eval_data is not None:
        pred = predict(e, params, eval_data)
        metrics = r2_rmse(pred, eval_data["tp"])
    return params, history, metrics


# ------------------------------------------------- recurrent (SSM) paths
def ssm_step_loss(c: SSMConfig, params, batch):
    """MSE (Mbps^2) of one stored-state replay step (the online loss).

    Each replay row carries the recurrent state *as it was* before the
    report — a constant under the gradient, so adaptation backprops
    through exactly one recurrence step (truncated BPTT, length 1). That
    is what keeps an online burst O(batch), independent of how much
    history each UE's state has absorbed."""
    _, fc = ssm_step(c, params, jax.lax.stop_gradient(batch["state"]),
                     batch["feats"])
    return jnp.mean((fc[..., 0] - batch["tp"]) ** 2)


def make_indexed_step_ssm(c: SSMConfig, opt: AdamW, *, mesh=None,
                          overrides=None):
    """:func:`make_indexed_step` for the recurrent estimator.

    Same contract — ``step(params, opt_state, data, idx, key) ->
    (params, opt_state, loss)`` with the minibatch gather inside the
    compiled program — over :data:`SSM_BATCH_KEYS`; ``key`` is accepted
    and ignored (the SSM forward has no dropout) so the online trainer
    drives both estimator families through one calling convention. The
    int8 ``(q, scales)`` ring form is not supported for recurrent rows:
    quantizing stored states would perturb every replayed gradient.
    """
    def _step(params, opt_state, data, idx, key):
        del key
        batch = {k: jnp.take(data[k], idx, axis=0) for k in SSM_BATCH_KEYS}
        loss, grads = jax.value_and_grad(
            lambda p: ssm_step_loss(c, p, batch))(params)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    if mesh is None:
        return jax.jit(_step)
    ov = dict(overrides or {})

    @jax.jit
    def sharded_step(params, opt_state, data, idx, key):
        with sh.use_rules(mesh, ov):
            return _step(params, opt_state, data, idx, key)

    return sharded_step


def ssm_seq_loss(c: SSMConfig, params, batch):
    """Teacher-forced sequence MSE: the whole (B, S) report trace runs
    through one chunked ``ssd_mixer`` pass and the labels sit on the
    last ``T`` steps (``S - T`` warmup reports precede the first label —
    the same WINDOW-offset convention the fleet engine reads estimates
    with)."""
    fc, _ = ssm_forward_seq(c, params, batch["feats"])
    t = batch["tp"].shape[1]
    off = batch["feats"].shape[1] - t
    return jnp.mean((fc[:, off - 1:off - 1 + t, 0] - batch["tp"]) ** 2)


def make_indexed_seq_step(c: SSMConfig, opt: AdamW):
    """Offline sequence-training step: gather whole UE traces by index
    inside jit (the sequence twin of :func:`make_indexed_step`)."""
    @jax.jit
    def step(params, opt_state, data, idx):
        batch = {k: jnp.take(data[k], idx, axis=0)
                 for k in ("feats", "tp")}
        loss, grads = jax.value_and_grad(
            lambda p: ssm_seq_loss(c, p, batch))(params)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step


def train_ssm(c: SSMConfig, data: dict, *, steps: int = 300,
              batch: int = 32, lr: float = 1e-3, seed: int = 0,
              log_every: int = 50, eval_data: dict | None = None):
    """Offline teacher-forced trainer for the recurrent estimator.

    ``data``: ``{"feats": (M, S, F), "tp": (M, T)}`` — per-UE report
    traces (``repro.estimator.ssm.episode_features``) and their last-T
    throughput labels. Mirrors :func:`train_estimator` (device-resident
    dataset, indexed gather, AdamW) so benchmark code swaps families by
    swapping the trainer."""
    params = init_ssm(c, jax.random.PRNGKey(seed))
    opt = AdamW(lr=lr, weight_decay=1e-4, clip_norm=1.0)
    opt_state = opt.init(params)
    step_fn = make_indexed_seq_step(c, opt)
    n = len(data["tp"])
    rng = np.random.default_rng(seed)
    data_dev = {k: jnp.asarray(data[k]) for k in ("feats", "tp")}
    history = []
    for i in range(steps):
        idx = rng.integers(0, n, batch)
        params, opt_state, loss = step_fn(params, opt_state, data_dev,
                                          jnp.asarray(idx, jnp.int32))
        if i % log_every == 0 or i == steps - 1:
            history.append((i, float(loss)))
    metrics = None
    if eval_data is not None:
        pred = ssm_predict(c, params, eval_data)
        metrics = r2_rmse(pred, eval_data["tp"])
    return params, history, metrics


def ssm_predict(c: SSMConfig, params, data: dict,
                batch: int | None = 64) -> np.ndarray:
    """(M, T) predicted Mbps for every trace row of ``data`` (sequence
    mode, labels-aligned tail — the eval twin of :func:`predict`)."""
    outs = []
    n, t = len(data["tp"]), data["tp"].shape[1]
    off = data["feats"].shape[1] - t
    batch = max(n, 1) if batch is None else batch
    for i in range(0, n, batch):
        fc, _ = ssm_forward_seq(c, params,
                                jnp.asarray(data["feats"][i:i + batch]))
        outs.append(np.asarray(fc[:, off - 1:off - 1 + t, 0]))
    return np.concatenate(outs)


@partial(jax.jit, static_argnums=0)
def fwd(e, params, kpms, iq, alloc):
    """One jitted inference forward (shared by ``predict`` and the
    unsharded per-period path of ``repro.sim.online``)."""
    return estimator_forward(e, params, kpms, iq, alloc)


def predict(e: EstimatorConfig, params, data: dict,
            batch: int | None = 64) -> np.ndarray:
    """Predicted throughput (Mbps) for every row of ``data``.

    ``batch=None`` runs the whole input through one forward pass — the
    fleet engine's per-report-period path (one ``predict`` per 0.1 s tick
    for all N UEs); an int chunks the input to bound peak memory."""
    outs = []
    n = len(data["tp"])
    batch = max(n, 1) if batch is None else batch
    for i in range(0, n, batch):
        outs.append(np.asarray(fwd(
            e, params, jnp.asarray(data["kpms"][i:i + batch]),
            jnp.asarray(data["iq"][i:i + batch]),
            jnp.asarray(data["alloc"][i:i + batch]))))
    return np.concatenate(outs)
