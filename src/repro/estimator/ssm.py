"""Recurrent SSM throughput estimator with K-period forecasts.

The windowed estimator (``estimator.model``) re-reads a (WINDOW, 15) KPM
window plus an IQ spectrogram every report period — O(WINDOW) featurize
work and ~2 KB of window buffer per UE per period. This module is the
O(1)-per-report alternative: each UE carries a constant-size SSD
recurrent state (the Mamba-2 recurrence of ``repro.kernels.ssd``), one
report updates it in place, and the readout emits the *current*
throughput estimate plus K-period-ahead forecasts by rolling the
recurrence forward in closed form — so the split controller can act on
where the channel is going, not only where it is.

Two execution modes share one parameter set:

  * **sequence mode** (``ssm_forward_seq``) — a whole (B, S) trace
    through ``ssd_mixer`` (chunked kernel or jnp oracle, pinned equal by
    ``tests/test_kernels.py``): offline training, the frozen fleet path,
    and state warmup;
  * **step mode** (``ssm_step``) — one report through ``ssd_step``: the
    online serving loop and the slot pool. A scan of steps reproduces
    the sequence pass (allclose; different accumulation order), pinned
    by ``tests/test_estimator_ssm.py``.

Inputs are the 15 normalized KPMs plus the PRB allocation ratio, and —
with ``SSMConfig(include_iq=True)`` — ``N_IQ_FEATS`` summary channels
of the period's IQ spectrogram snapshot (``iq_features``). The snapshot
is an instantaneous input, not carried history, so the O(1)-per-report
cost and the constant state are untouched; without it the estimator is
blind exactly where KPMs are blind (low-load + zero-overlap
interference, the paper's Fig. 2b regime). The trade-off is documented
in docs/estimator.md.

Forecast rollout, in closed form: holding the last input u, per head
``y_{t+j} = d^j y_t + (sum_{i<j} d^i) * dt * (C.B) * u`` with
``d = exp(dt*A)`` — K extra readouts, no extra state. ``forecast_policy``
collapses the (K+1) forecasts to the one effective throughput the
(unchanged) controller consumes; ``forecast_horizon=0`` is pinned
bit-identical to the plain current estimate.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel import kpm as kpmmod
from repro.channel.scenarios import WINDOW
from repro.dist.sharding import constrain
from repro.kernels.ssd import ssd_mixer, ssd_step
from repro.models.template import ParamSpec, init_from_template

F32 = jnp.float32

FORECAST_POLICIES = ("last", "min", "discount")
N_IQ_FEATS = 6  # summary channels ``iq_features`` derives per snapshot


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Hashable config of the recurrent estimator (a jit static arg).

    ``forecast_horizon`` K adds K rolled-forward readouts per estimate;
    ``forecast_policy`` reduces them to one effective Mbps ("last" = the
    current estimate, "min" = plan for the worst forecast period,
    "discount" = gamma-weighted mean). ``use_kernel`` routes sequence
    passes through the Pallas SSD kernel instead of the jnp oracle —
    keep False on CPU hosts (interpret-mode Pallas is for parity tests);
    the O(1) step path is jnp either way, so it shards under a mesh.
    """

    n_kpms: int = 15
    n_heads: int = 4
    head_dim: int = 8
    n_groups: int = 1
    state_dim: int = 8  # N — SSD state columns per group
    hidden: int = 32  # readout MLP width
    forecast_horizon: int = 0  # K periods rolled forward
    forecast_policy: str = "last"
    forecast_discount: float = 0.8
    chunk: int = 64  # ssd_mixer chunk length for sequence passes
    use_kernel: bool = False
    # append per-period IQ summary channels (``iq_features``) to the
    # report row: the spectrogram snapshot is an *instantaneous* input —
    # no carried history — so the O(1)-per-report serving cost and the
    # constant state are unchanged; this is what lets the recurrent
    # estimator see interference where KPMs are blind (low-load jamming,
    # the paper's Fig. 2b regime).
    include_iq: bool = False

    def __post_init__(self):
        if self.n_heads % self.n_groups:
            raise ValueError(f"n_heads ({self.n_heads}) must divide into "
                             f"n_groups ({self.n_groups})")
        if self.forecast_policy not in FORECAST_POLICIES:
            raise ValueError(f"forecast_policy must be one of "
                             f"{FORECAST_POLICIES}: {self.forecast_policy!r}")
        if self.forecast_horizon < 0:
            raise ValueError(
                f"forecast_horizon must be >= 0: {self.forecast_horizon}")

    @property
    def n_feats(self) -> int:
        # 15 KPMs + the PRB allocation ratio (+ IQ summary channels)
        return self.n_kpms + 1 + (N_IQ_FEATS if self.include_iq else 0)

    @property
    def heads_per_group(self) -> int:
        return self.n_heads // self.n_groups

    def state_shape(self) -> tuple:
        """Per-UE recurrent state (the ssd carried-state layout)."""
        return (self.n_groups, self.heads_per_group, self.head_dim,
                self.state_dim)

    def state_bytes(self) -> int:
        """f32 bytes of recurrent state one UE costs the serving fleet."""
        return int(np.prod(self.state_shape())) * 4


def ssm_template(c: SSMConfig):
    f, nh, hd = c.n_feats, c.n_heads, c.head_dim
    gn = c.n_groups * c.state_dim
    return {
        "in": {
            "wu": ParamSpec((f, nh * hd), (None, None)),
            "wdt": ParamSpec((f, nh), (None, None)),
            # softplus(0) ~ 0.69 -> per-period decay ~ exp(-0.69) at A=-1:
            # a half-life of one report period before training moves it
            "bdt": ParamSpec((nh,), (None,), init="zeros"),
            "wb": ParamSpec((f, gn), (None, None)),
            "wc": ParamSpec((f, gn), (None, None)),
            "a_log": ParamSpec((nh,), (None,), init="zeros"),  # A = -e^a
        },
        # RMSNorm gain on the mixer output: the SSD state's steady-state
        # magnitude is input- and decay-dependent (decay -> 1 grows it
        # without bound), so the readout sees a normalized y no matter
        # where the dynamics settle — same role as Mamba-2's post-mixer
        # norm, and what keeps length extrapolation + online adaptation
        # stable.
        "norm": {"g": ParamSpec((nh * hd,), (None,), init="ones")},
        "head": {
            "w1": ParamSpec((nh * hd + f, c.hidden), (None, None)),
            "b1": ParamSpec((c.hidden,), (None,), init="zeros"),
            "w2": ParamSpec((c.hidden, 1), (None, None)),
            "b2": ParamSpec((1,), (None,), init="zeros"),
        },
    }


def init_ssm(c: SSMConfig, key):
    return init_from_template(ssm_template(c), key)


def ssm_state_init(c: SSMConfig, batch_shape: tuple = ()) -> jax.Array:
    return jnp.zeros(tuple(batch_shape) + c.state_shape(), F32)


def iq_features(iq: np.ndarray) -> np.ndarray:
    """(..., 2, n_sc, 14) IQ spectrogram snapshots -> (..., N_IQ_FEATS)
    summary channels, O(n_sc) per snapshot (no history, no learned
    weights): total log-power, narrowband peak, symbol burstiness (tdd),
    high/low subband contrast (cci), tail power, and occupancy — the
    interference signatures the windowed estimator's CNN learns from the
    same snapshot."""
    x = np.asarray(iq, np.float32)
    p = x[..., 0, :, :] ** 2 + x[..., 1, :, :] ** 2  # (..., n_sc, 14)
    n_sc = p.shape[-2]
    psc = p.mean(-1)  # (..., n_sc) per-subcarrier power
    psym = p.mean(-2)  # (..., 14)  per-symbol power
    lo = psc[..., :n_sc // 2].mean(-1)
    hi = psc[..., n_sc // 2:].mean(-1)
    med = np.median(p, axis=(-2, -1))
    feats = np.stack([
        np.log1p(p.mean((-2, -1))),
        np.log1p(psc.max(-1)),
        np.log1p(psym).std(-1),
        np.log1p(hi) - np.log1p(lo),
        np.log1p(np.quantile(p, 0.95, axis=(-2, -1))),
        (p > 2.0 * med[..., None, None] + 1e-6).mean((-2, -1)),
    ], axis=-1)
    return feats.astype(np.float32)


def episode_features(kpms: np.ndarray, alloc_ratio: np.ndarray,
                     iq: np.ndarray | None = None) -> np.ndarray:
    """(N, S, F) f32 report-stream features from raw (N, S, 15) KPM
    reports + (N,) PRB ratios: the fixed-affine KPM normalisation
    (``channel.kpm.normalize_kpms``) with the clipped alloc ratio
    broadcast as a 16th channel — everything the recurrent estimator
    consumes (no windows).

    ``iq`` (N, T, 2, n_sc, 14) — the per-period spectrogram snapshots of
    an ``include_iq`` episode — appends ``N_IQ_FEATS`` summary channels
    (``iq_features``). The trace is S = T + WINDOW reports long but IQ
    exists only for the T report periods; period ``t``'s snapshot lands
    on the sequence index the estimator reads for period ``t``
    (``WINDOW - 1 + t``), and the warm-up prefix carries zeros (no
    estimate is read there)."""
    k = kpmmod.normalize_kpms(np.asarray(kpms)).astype(np.float32)
    n, s = k.shape[:2]
    a = np.broadcast_to(
        np.clip(np.asarray(alloc_ratio, np.float32), 0.0, 1.0)[:, None, None],
        (n, s, 1))
    cols = [k, a]
    if iq is not None:
        t = np.asarray(iq).shape[1]
        if t + WINDOW > s:
            raise ValueError(f"iq has {t} periods but the trace only "
                             f"fits {s - WINDOW}")
        iqf = np.zeros((n, s, N_IQ_FEATS), np.float32)
        iqf[:, WINDOW - 1:WINDOW - 1 + t] = iq_features(iq)
        cols.append(iqf)
    return np.concatenate(cols, axis=-1)


def _project(c: SSMConfig, params, feats):
    """feats (..., F) -> (u (..., nh, hd), dt (..., nh), Bm/Cm
    (..., G, N), A (nh,))."""
    p = params["in"]
    lead = feats.shape[:-1]
    u = (feats @ p["wu"]).reshape(lead + (c.n_heads, c.head_dim))
    dt = jax.nn.softplus(feats @ p["wdt"] + p["bdt"])
    bm = (feats @ p["wb"]).reshape(lead + (c.n_groups, c.state_dim))
    cm = (feats @ p["wc"]).reshape(lead + (c.n_groups, c.state_dim))
    return u, dt, bm, cm, -jnp.exp(params["in"]["a_log"])


def _readout(c: SSMConfig, params, y, feats):
    """(y (..., nh, hd), feats (..., F)) -> (...) Mbps."""
    p = params["head"]
    yf = y.reshape(y.shape[:-2] + (c.n_heads * c.head_dim,))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
    h = jnp.concatenate([yf * params["norm"]["g"], feats], -1)
    h = jax.nn.relu(h @ p["w1"] + p["b1"])
    return (h @ p["w2"] + p["b2"])[..., 0]


def _forecast_readout(c: SSMConfig, params, y, u, dt, bm, cm, feats, A):
    """(..., K+1) readouts: index 0 is the current estimate, index j the
    j-period-ahead forecast from the closed-form rollout (input held)."""
    outs = [_readout(c, params, y, feats)]
    if c.forecast_horizon:
        d = jnp.exp(dt * A)  # (..., nh) per-head one-period decay
        cb = jnp.sum(cm * bm, -1)  # (..., G) — C.B contraction
        cbh = jnp.repeat(cb, c.heads_per_group, axis=-1)  # groups -> heads
        inj = (dt * cbh)[..., None] * u  # the held input's per-step push
        yj = y
        for _ in range(c.forecast_horizon):
            yj = d[..., None] * yj + inj
            outs.append(_readout(c, params, yj, feats))
    return jnp.stack(outs, -1)


@partial(jax.jit, static_argnums=0)
def ssm_forward_seq(c: SSMConfig, params, feats):
    """Sequence mode: feats (B, S, F) -> ((B, S, K+1) forecasts, final
    state (B,) + ``c.state_shape()``).

    The whole trace runs through one ``ssd_mixer`` call (chunk =
    ``min(c.chunk, S)``; the trace is padded to a chunk multiple with
    dt=0 rows, which leave the state untouched — exp(0)=1 decay, zero
    input — and are sliced off the outputs). Step ``s``'s forecasts see
    reports 0..s, so period ``t`` of an EpisodeBatch trace reads index
    ``WINDOW + t - 1``."""
    feats = constrain(feats.astype(F32), ("batch", None, None))
    u, dt, bm, cm, A = _project(c, params, feats)
    s = feats.shape[1]
    chunk = min(c.chunk, s)
    pad = -s % chunk
    if pad:
        pz = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),)
                               * (a.ndim - 2))
        y, state = ssd_mixer(pz(u), pz(dt), A, pz(bm), pz(cm), chunk=chunk,
                             use_kernel=c.use_kernel)
        y = y[:, :s]
    else:
        y, state = ssd_mixer(u, dt, A, bm, cm, chunk=chunk,
                             use_kernel=c.use_kernel)
    fc = _forecast_readout(c, params, y, u, dt, bm, cm, feats, A)
    return constrain(fc, ("batch", None, None)), state


@partial(jax.jit, static_argnums=0)
def ssm_step(c: SSMConfig, params, state, feats):
    """Step mode: one report per UE, O(1) in history length.

    ``state`` (B,) + ``c.state_shape()``; ``feats`` (B, F). Returns
    (new state, (B, K+1) forecasts). Pure jnp (``ssd_step``), so under a
    ``dist.sharding`` ruleset both the state and the report batch shard
    over the mesh's ``batch`` axis with replicated weights."""
    feats = constrain(feats.astype(F32), ("batch", None))
    state = constrain(state.astype(F32), ("batch",) + (None,) * 4)
    u, dt, bm, cm, A = _project(c, params, feats)
    y, state = ssd_step(u, dt, A, bm, cm, state)
    fc = _forecast_readout(c, params, y, u, dt, bm, cm, feats, A)
    return (constrain(state, ("batch",) + (None,) * 4),
            constrain(fc, ("batch", None)))


def reduce_forecasts(c: SSMConfig, fc: np.ndarray) -> np.ndarray:
    """(..., K+1) forecasts -> (...) effective Mbps per the policy.

    Host-side numpy on purpose: the reduce is trivial, and keeping it out
    of the jitted programs means every engine path (batch, online, pool,
    sharded) collapses forecasts identically. K=0 returns column 0
    unchanged under every policy — the bit-identity pin."""
    fc = np.asarray(fc)
    if c.forecast_horizon == 0 or c.forecast_policy == "last":
        return fc[..., 0]
    if c.forecast_policy == "min":
        return fc.min(axis=-1)
    w = c.forecast_discount ** np.arange(fc.shape[-1], dtype=np.float64)
    w /= w.sum()
    return fc @ w.astype(fc.dtype)


def ssm_warm_state(c: SSMConfig, params, feats_prefix) -> jax.Array:
    """Final recurrent state after consuming a (B, W, F) warmup prefix —
    how the serving paths seed a UE's state from the WINDOW-1 reports
    that precede its first estimate."""
    _, state = ssm_forward_seq(c, params, jnp.asarray(feats_prefix))
    return state
