from repro.estimator import baselines, model, ssm, train  # noqa: F401
