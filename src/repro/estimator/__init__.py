from repro.estimator import baselines, model, train  # noqa: F401
