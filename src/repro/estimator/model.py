"""AI-based throughput estimator (paper Fig. 3 + Table I).

Branch 1: LSTM (hidden 124, window 30) over the 15 numerical KPMs.
Branch 2: CNN over the (2, 273*12, 14) IQ spectrogram:
    conv3x3(16) - relu - maxpool2 - conv3x3(32) - relu - maxpool2 -
    flatten - linear(hidden) - relu - dropout
Fusion: weighted sum with w = allocated-PRB ratio (KPMs are trustworthy
exactly when the UE's grant covers the band), then an FC regression head.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import constrain
from repro.models.template import ParamSpec, init_from_template

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class EstimatorConfig:
    n_kpms: int = 15
    window: int = 30
    lstm_hidden: int = 124
    hidden: int = 124
    n_sc: int = 3276
    n_sym: int = 14
    cnn_ch: tuple = (16, 32)
    dropout: float = 0.1

    @property
    def cnn_flat(self) -> int:
        return self.cnn_ch[1] * (self.n_sc // 4) * (self.n_sym // 4)


def estimator_template(e: EstimatorConfig):
    c1, c2 = e.cnn_ch
    h = e.lstm_hidden
    return {
        "lstm": {
            "wx": ParamSpec((e.n_kpms, 4 * h), (None, None)),
            "wh": ParamSpec((h, 4 * h), (None, None)),
            "b": ParamSpec((4 * h,), (None,), init="zeros"),
            "proj": ParamSpec((h, e.hidden), (None, None)),
        },
        "cnn": {
            "conv1": ParamSpec((3, 3, 2, c1), (None,) * 4),
            "b1": ParamSpec((c1,), (None,), init="zeros"),
            "conv2": ParamSpec((3, 3, c1, c2), (None,) * 4),
            "b2": ParamSpec((c2,), (None,), init="zeros"),
            "fc": ParamSpec((e.cnn_flat, e.hidden), (None, None)),
            "fcb": ParamSpec((e.hidden,), (None,), init="zeros"),
        },
        "head": {
            "w1": ParamSpec((e.hidden, e.hidden), (None, None)),
            "b1": ParamSpec((e.hidden,), (None,), init="zeros"),
            "w2": ParamSpec((e.hidden, 1), (None, None)),
            "b2": ParamSpec((1,), (None,), init="zeros"),
        },
    }


def init_estimator(e: EstimatorConfig, key):
    return init_from_template(estimator_template(e), key)


def lstm_branch(p, kpms):
    """kpms: (B, T, K) -> (B, hidden)."""
    B = kpms.shape[0]
    h0 = jnp.zeros((B, p["wh"].shape[0]), F32)
    c0 = jnp.zeros_like(h0)

    def cell(carry, x_t):
        h, c = carry
        z = x_t @ p["wx"] + h @ p["wh"] + p["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    (h, _), _ = lax.scan(cell, (h0, c0), kpms.transpose(1, 0, 2))
    return h @ p["proj"]


def cnn_branch(p, iq, *, dropout_rate=0.0, key=None):
    """iq: (B, 2, S, 14) -> (B, hidden)."""
    x = iq.transpose(0, 2, 3, 1)  # NHWC
    for w, b in ((p["conv1"], p["b1"]), (p["conv2"], p["b2"])):
        x = lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + b)
        x = lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p["fc"] + p["fcb"])
    if dropout_rate and key is not None:
        keep = jax.random.bernoulli(key, 1 - dropout_rate, x.shape)
        x = x * keep / (1 - dropout_rate)
    return x


def estimator_forward(e: EstimatorConfig, params, kpms, iq, alloc, *,
                      train: bool = False, key=None):
    """Returns predicted max throughput in Mbps, shape (B,).

    The B dim carries the logical ``batch`` axis: under an active
    ``dist.sharding`` ruleset (the fleet serving path, see
    ``repro.sim.serving``) the UE batch shards over the mesh's data axis
    while the weights — whose template axes are all ``None`` — stay
    replicated. Outside a ruleset every ``constrain`` is the identity, so
    training and CPU tests run this code unchanged.
    """
    kpms = constrain(kpms.astype(F32), ("batch", None, None))
    iq = constrain(iq.astype(F32), ("batch", None, None, None))
    alloc = constrain(alloc.astype(F32), ("batch",))
    v_t = lstm_branch(params["lstm"], kpms)
    v_s = cnn_branch(params["cnn"], iq,
                     dropout_rate=e.dropout if train else 0.0, key=key)
    w = jnp.clip(alloc, 0.0, 1.0)[:, None]
    fused = constrain(w * v_t + (1.0 - w) * v_s, ("batch", "embed"))
    h = jax.nn.relu(fused @ params["head"]["w1"] + params["head"]["b1"])
    out = h @ params["head"]["w2"] + params["head"]["b2"]
    return constrain(out[:, 0], ("batch",))
