"""Table II baselines.

The paper compares XGBoost on 7 KPMs [8] and on 15 KPMs against the proposed
two-branch model. xgboost is unavailable offline, so the tree learner is
replaced by (a) closed-form ridge regression and (b) a small MLP on the same
summary features — the reproduction target is the feature-set ORDERING
(7 KPMs < 15 KPMs < KPM-timeseries + IQ), not the tree implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel.kpm import KPMS_15, KPMS_7
from repro.optim import AdamW


def summary_features(kpms: np.ndarray, feature_set: str) -> np.ndarray:
    """(B, W, 15) windows -> per-sample features: last, mean, std, delta."""
    idx = {
        "kpm7": [KPMS_15.index(k) for k in KPMS_7],
        "kpm15": list(range(len(KPMS_15))),
    }[feature_set]
    x = kpms[:, :, idx]
    feats = np.concatenate([
        x[:, -1], x.mean(1), x.std(1), x[:, -1] - x[:, 0]], axis=1)
    return feats.astype(np.float32)


def ridge_fit(X: np.ndarray, y: np.ndarray, lam: float = 1.0):
    Xb = np.concatenate([X, np.ones((len(X), 1), X.dtype)], axis=1)
    A = Xb.T @ Xb + lam * np.eye(Xb.shape[1], dtype=X.dtype)
    w = np.linalg.solve(A, Xb.T @ y)
    return w


def ridge_predict(w: np.ndarray, X: np.ndarray) -> np.ndarray:
    Xb = np.concatenate([X, np.ones((len(X), 1), X.dtype)], axis=1)
    return Xb @ w


def constant_floor(ytr: np.ndarray, yte: np.ndarray) -> float:
    """RMSE of the train-mean constant predictor — the floor any learned
    estimator must beat for its Table II row to mean anything."""
    ytr, yte = np.asarray(ytr, float), np.asarray(yte, float)
    return float(np.sqrt(np.mean((yte - ytr.mean()) ** 2)))


def persistence_rmse(tp: np.ndarray, horizon: int = 1) -> float:
    """RMSE of the persistence predictor ``est_t = tp_{t-horizon}`` over
    an (N, T) throughput trace (first ``horizon`` periods skipped — no
    prediction exists there). The naive *temporal* floor the recurrent
    estimator's K-period forecasts are judged against: a forecaster that
    can't beat "tomorrow equals today" isn't forecasting."""
    tp = np.asarray(tp, float)
    if horizon < 1 or horizon >= tp.shape[1]:
        raise ValueError(f"horizon must be in [1, T): {horizon}")
    err = tp[:, horizon:] - tp[:, :-horizon]
    return float(np.sqrt(np.mean(err ** 2)))


def mlp_fit_predict(Xtr, ytr, Xte, *, hidden: int = 64, steps: int = 400,
                    seed: int = 0):
    """2-layer MLP regressor (the stronger non-tree baseline)."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    d = Xtr.shape[1]
    params = {
        "w1": jax.random.normal(k1, (d, hidden)) / np.sqrt(d),
        "b1": jnp.zeros(hidden),
        "w2": jax.random.normal(k2, (hidden, 1)) / np.sqrt(hidden),
        "b2": jnp.zeros(1),
    }
    opt = AdamW(lr=3e-3, weight_decay=1e-4)
    st = opt.init(params)

    @jax.jit
    def step(params, st, X, y):
        def loss_fn(p):
            h = jax.nn.relu(X @ p["w1"] + p["b1"])
            pred = (h @ p["w2"] + p["b2"])[:, 0]
            return jnp.mean((pred - y) ** 2)

        loss, g = jax.value_and_grad(loss_fn)(params)
        params, st, _ = opt.update(g, st, params)
        return params, st, loss

    Xtr_j, ytr_j = jnp.asarray(Xtr), jnp.asarray(ytr)
    rng = np.random.default_rng(seed)
    for i in range(steps):
        idx = rng.integers(0, len(Xtr), 64)
        params, st, _ = step(params, st, Xtr_j[idx], ytr_j[idx])
    h = jax.nn.relu(jnp.asarray(Xte) @ params["w1"] + params["b1"])
    return np.asarray((h @ params["w2"] + params["b2"])[:, 0])
