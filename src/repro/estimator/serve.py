"""int8 estimator serving: quantized weights, integer matmuls, fp32 out.

The frozen serving path's FLOPs are dominated by dense matmuls — the
LSTM's 30-step recurrence and the FC layers (LSTM projection, CNN fc,
regression head). This module pre-quantizes those weights rowwise per
output channel with the existing ``kernels/quant`` quantizer and serves
them through the int8 Pallas kernels (``kernels/lstm``'s quantized scan,
``kernels/qmm``'s int8 x int8 -> int32 matmul): one quarter the weight
bytes, integer MXU throughput, activations quantized rowwise on the fly.
The two 3x3 convolutions (a negligible FLOP share with no matmul form)
and all biases stay fp32.

Numerics: integer accumulation is exact, so ``use_kernel`` only moves
*where* the math runs — the Pallas kernels and the jnp oracles produce
bit-identical outputs, which is also why serving meshes (where GSPMD
cannot partition a ``pallas_call``) run ``use_kernel=False`` with
nothing lost. The int8-vs-fp32 accuracy cost is pinned by
``tests/test_sim_fused.py`` and measured by ``benchmarks/fleet.py``.
The fp32 path (``quant=None`` everywhere) never enters this module.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.dist.sharding import constrain
from repro.estimator.model import EstimatorConfig
from repro.kernels.lstm.ops import lstm_hidden_q
from repro.kernels.qmm.ops import int8_matmul, quantize_weight

F32 = jnp.float32

QUANT_MODES = (None, "int8")


def check_quant(quant) -> None:
    """Validate a ``quant=`` argument (shared by every serving entry)."""
    if quant not in QUANT_MODES:
        raise ValueError(f"quant must be one of {QUANT_MODES}: {quant!r}")


def quantize_estimator(params, *, use_kernel: bool = True,
                       interpret: bool = True):
    """fp32 estimator params -> the int8 serving tree.

    Every dense matmul weight (LSTM input/recurrent, LSTM projection, CNN
    fc, both head layers) becomes an ((OUT, IN) int8, (OUT, 1) f32 scale)
    pair — ``kernels/quant`` rowwise quantization of ``w.T``, one scale
    per output channel. Biases and the 3x3 conv filters stay fp32. The
    tree is a plain pytree (tuples for quantized leaves), so
    ``serving.replicate_params`` and jit treat it like any params tree."""
    q = partial(quantize_weight, use_kernel=use_kernel, interpret=interpret)
    lstm, cnn, head = params["lstm"], params["cnn"], params["head"]
    return {
        "lstm": {"wx": q(lstm["wx"]), "wh": q(lstm["wh"]),
                 "b": lstm["b"], "proj": q(lstm["proj"])},
        "cnn": {"conv1": cnn["conv1"], "b1": cnn["b1"],
                "conv2": cnn["conv2"], "b2": cnn["b2"],
                "fc": q(cnn["fc"]), "fcb": cnn["fcb"]},
        "head": {"w1": q(head["w1"]), "b1": head["b1"],
                 "w2": q(head["w2"]), "b2": head["b2"]},
    }


def _conv_trunk(p, iq):
    """The fp32 conv/pool trunk of ``model.cnn_branch`` (everything up to
    the fc layer, which the int8 path runs quantized)."""
    x = iq.transpose(0, 2, 3, 1)  # NHWC
    for w, b in ((p["conv1"], p["b1"]), (p["conv2"], p["b2"])):
        x = lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + b)
        x = lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    return x.reshape(x.shape[0], -1)


def estimator_forward_int8(e: EstimatorConfig, qparams, kpms, iq, alloc, *,
                           use_kernel: bool = True, interpret: bool = True):
    """The serving forward on a ``quantize_estimator`` tree: (B,) Mbps.

    Mirrors ``model.estimator_forward`` (inference mode) with every dense
    matmul routed through the int8 kernels; the ``constrain`` annotations
    are kept so the ``use_kernel=False`` form shards under a serving mesh
    exactly like the fp32 program."""
    kpms = constrain(kpms.astype(F32), ("batch", None, None))
    iq = constrain(iq.astype(F32), ("batch", None, None, None))
    alloc = constrain(alloc.astype(F32), ("batch",))
    lq, cq, hq = qparams["lstm"], qparams["cnn"], qparams["head"]
    mm = partial(int8_matmul, use_kernel=use_kernel, interpret=interpret)
    h = lstm_hidden_q(kpms, lq["wx"][0], lq["wx"][1], lq["wh"][0],
                      lq["wh"][1], lq["b"], use_kernel=use_kernel,
                      interpret=interpret)
    v_t = mm(h, *lq["proj"])
    v_s = jax.nn.relu(mm(_conv_trunk(cq, iq), *cq["fc"]) + cq["fcb"])
    w = jnp.clip(alloc, 0.0, 1.0)[:, None]
    fused = constrain(w * v_t + (1.0 - w) * v_s, ("batch", "embed"))
    hh = jax.nn.relu(mm(fused, *hq["w1"]) + hq["b1"])
    out = mm(hh, *hq["w2"]) + hq["b2"]
    return constrain(out[:, 0], ("batch",))


@partial(jax.jit, static_argnums=0,
         static_argnames=("use_kernel", "interpret"))
def fwd_int8(e, qparams, kpms, iq, alloc, *, use_kernel=True,
             interpret=True):
    """One jitted int8 inference forward (the ``estimator.train.fwd``
    counterpart the fused engine path calls per chunk)."""
    return estimator_forward_int8(e, qparams, kpms, iq, alloc,
                                  use_kernel=use_kernel, interpret=interpret)


def predict_int8(e: EstimatorConfig, qparams, data: dict,
                 batch: int | None = 64, *, use_kernel: bool = True,
                 interpret: bool = True) -> np.ndarray:
    """int8 twin of ``estimator.train.predict`` — Mbps for every row."""
    outs = []
    n = len(data["tp"])
    batch = max(n, 1) if batch is None else batch
    for i in range(0, n, batch):
        outs.append(np.asarray(fwd_int8(
            e, qparams, jnp.asarray(data["kpms"][i:i + batch]),
            jnp.asarray(data["iq"][i:i + batch]),
            jnp.asarray(data["alloc"][i:i + batch]),
            use_kernel=use_kernel, interpret=interpret)))
    return np.concatenate(outs)
