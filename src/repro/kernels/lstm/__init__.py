from repro.kernels.lstm.kernel import lstm_scan, lstm_scan_q  # noqa: F401
from repro.kernels.lstm.ops import lstm_hidden, lstm_hidden_q  # noqa: F401
from repro.kernels.lstm.ref import (lstm_scan_q_ref,  # noqa: F401
                                    lstm_scan_ref, qdot_ref)
