"""Fused LSTM-cell scan as a Pallas kernel (fp32 + int8 serving variants).

The throughput estimator's temporal branch is a 30-step LSTM over each
UE's KPM window (``estimator.model.lstm_branch``): per step a
(B, K) @ (K, 4H) input projection, a (B, H) @ (H, 4H) recurrence, and the
gate chain. As XLA ops that is a ``lax.scan`` of ~10 small kernels per
step; here the whole scan runs inside one grid step per batch tile —
weights and the (h, c) carry stay resident in VMEM across all 30 steps,
and the matmul + gates + elementwise chain fuses into one kernel.

The int8 variant is the serving path's quantized LSTM: weights arrive
pre-quantized rowwise per *output* channel (the ``kernels/quant``
formula, applied to ``w.T``), activations are dynamically quantized
per row each step inside the kernel, and both projections run as
int8 x int8 -> int32 MXU dots scaled back to f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32
I32 = jnp.int32

# contract the LAST axis of both operands: (B, K) x (OUT, K) -> (B, OUT),
# the layout int8 weights are stored in (rowwise quantization of w.T)
_CONTRACT_LAST = (((1,), (1,)), ((), ()))


def _gates(z, c):
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def _rowq(x, qmax):
    # the kernels/quant rowwise symmetric formula, inlined (a kernel body
    # cannot nest a pallas_call); reciprocal multiply keeps it
    # bit-identical with quantize_ref / the quant kernel
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) * jnp.float32(1.0 / qmax)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q, scale


def _lstm_kernel(x_ref, wx_ref, wh_ref, b_ref, o_ref, *, t_steps, hidden):
    x = x_ref[...].astype(F32)  # (bn, T, K)
    wx, wh, bias = wx_ref[...], wh_ref[...], b_ref[...]
    bn = x.shape[0]
    h0 = jnp.zeros((bn, hidden), F32)

    def step(t, carry):
        h, c = carry
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, axis=1)[:, 0]
        z = (jnp.dot(x_t, wx, preferred_element_type=F32)
             + jnp.dot(h, wh, preferred_element_type=F32) + bias)
        return _gates(z, c)

    h, _ = jax.lax.fori_loop(0, t_steps, step, (h0, jnp.zeros_like(h0)))
    o_ref[...] = h


def _lstm_q_kernel(x_ref, wxq_ref, wxs_ref, whq_ref, whs_ref, b_ref, o_ref,
                   *, t_steps, hidden, qmax):
    x = x_ref[...].astype(F32)
    wxq, whq = wxq_ref[...], whq_ref[...]  # (4H, K) / (4H, H) int8
    wxs, whs = wxs_ref[...].T, whs_ref[...].T  # (1, 4H) per-column scales
    bias = b_ref[...]
    bn = x.shape[0]
    h0 = jnp.zeros((bn, hidden), F32)

    def qdot(a, wq, ws):
        qa, sa = _rowq(a, qmax)
        acc = jax.lax.dot_general(qa, wq, _CONTRACT_LAST,
                                  preferred_element_type=I32)
        return acc.astype(F32) * sa * ws

    def step(t, carry):
        h, c = carry
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, axis=1)[:, 0]
        z = qdot(x_t, wxq, wxs) + qdot(h, whq, whs) + bias
        return _gates(z, c)

    h, _ = jax.lax.fori_loop(0, t_steps, step, (h0, jnp.zeros_like(h0)))
    o_ref[...] = h


def lstm_scan(kpms, wx, wh, b, *, block_rows: int = 128,
              interpret: bool = True):
    """kpms (B, T, K), wx (K, 4H), wh (H, 4H), b (4H,) -> final h (B, H)."""
    n, t_steps, k = kpms.shape
    hidden = wh.shape[0]
    bn = min(block_rows, n)
    pad = (-n) % bn
    if pad:
        kpms = jnp.pad(kpms, ((0, pad), (0, 0), (0, 0)))
    npad = n + pad
    kernel = functools.partial(_lstm_kernel, t_steps=t_steps, hidden=hidden)
    out = pl.pallas_call(
        kernel,
        grid=(npad // bn,),
        in_specs=[
            pl.BlockSpec((bn, t_steps, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((k, 4 * hidden), lambda i: (0, 0)),
            pl.BlockSpec((hidden, 4 * hidden), lambda i: (0, 0)),
            pl.BlockSpec((1, 4 * hidden), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, hidden), F32),
        interpret=interpret,
    )(kpms.astype(F32), jnp.asarray(wx, F32), jnp.asarray(wh, F32),
      jnp.asarray(b, F32).reshape(1, -1))
    return out[:n]


def lstm_scan_q(kpms, wxq, wxs, whq, whs, b, *, qmax: int = 127,
                block_rows: int = 128, interpret: bool = True):
    """int8-serving LSTM scan -> final h (B, H) in f32.

    ``wxq`` (4H, K) / ``whq`` (4H, H): int8 weights quantized rowwise per
    output channel (``quantize_rows(w.T)``); ``wxs`` / ``whs`` (4H, 1):
    their f32 scales. Activations are quantized per row, per step, inside
    the kernel."""
    n, t_steps, k = kpms.shape
    hidden = whq.shape[1]
    bn = min(block_rows, n)
    pad = (-n) % bn
    if pad:
        kpms = jnp.pad(kpms, ((0, pad), (0, 0), (0, 0)))
    npad = n + pad
    kernel = functools.partial(_lstm_q_kernel, t_steps=t_steps,
                               hidden=hidden, qmax=qmax)
    out = pl.pallas_call(
        kernel,
        grid=(npad // bn,),
        in_specs=[
            pl.BlockSpec((bn, t_steps, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((4 * hidden, k), lambda i: (0, 0)),
            pl.BlockSpec((4 * hidden, 1), lambda i: (0, 0)),
            pl.BlockSpec((4 * hidden, hidden), lambda i: (0, 0)),
            pl.BlockSpec((4 * hidden, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 4 * hidden), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, hidden), F32),
        interpret=interpret,
    )(kpms.astype(F32), wxq, jnp.asarray(wxs, F32), whq,
      jnp.asarray(whs, F32), jnp.asarray(b, F32).reshape(1, -1))
    return out[:n]
