"""jit dispatch for the fused LSTM-cell scan (fp32 + int8 serving)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.lstm.kernel import lstm_scan, lstm_scan_q
from repro.kernels.lstm.ref import lstm_scan_q_ref, lstm_scan_ref


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def lstm_hidden(kpms, wx, wh, b, *, use_kernel: bool = True,
                interpret: bool = True):
    """(B, T, K) windows -> (B, H) final hidden state, fp32.

    The estimator's temporal branch minus its output projection:
    ``lstm_hidden(...) @ proj == lstm_branch(p, kpms)`` to f32 tolerance."""
    if use_kernel:
        return lstm_scan(kpms, wx, wh, b, interpret=interpret)
    return lstm_scan_ref(kpms, wx, wh, b)


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def lstm_hidden_q(kpms, wxq, wxs, whq, whs, b, *, use_kernel: bool = True,
                  interpret: bool = True):
    """int8-serving variant: pre-quantized weights (``quantize_rows(w.T)``
    layout), per-step dynamic activation quantization."""
    if use_kernel:
        return lstm_scan_q(kpms, wxq, wxs, whq, whs, b, interpret=interpret)
    return lstm_scan_q_ref(kpms, wxq, wxs, whq, whs, b)
