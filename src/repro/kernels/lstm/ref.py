"""jnp oracles for the fused LSTM-cell kernels.

``lstm_scan_ref`` is the ``estimator.model.lstm_branch`` scan without the
final projection (the kernel's contract: it returns the last hidden
state); ``lstm_scan_q_ref`` is the int8 serving variant — dynamically
quantized activations (``core.boundary.rowwise_quant``, the same formula
the kernel inlines) against pre-quantized per-output-channel weights,
int8 x int8 -> int32 dots scaled back to f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.boundary import rowwise_quant

F32 = jnp.float32
I32 = jnp.int32

_CONTRACT_LAST = (((1,), (1,)), ((), ()))


def _gates(z, c):
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def lstm_scan_ref(kpms, wx, wh, b):
    """kpms (B, T, K) -> final hidden state (B, H), f32."""
    kpms = jnp.asarray(kpms, F32)
    wx, wh, b = (jnp.asarray(a, F32) for a in (wx, wh, b))
    h0 = jnp.zeros((kpms.shape[0], wh.shape[0]), F32)

    def cell(carry, x_t):
        h, c = carry
        h, c = _gates(x_t @ wx + h @ wh + b, c)
        return (h, c), None

    (h, _), _ = lax.scan(cell, (h0, jnp.zeros_like(h0)),
                         kpms.transpose(1, 0, 2))
    return h


def qdot_ref(a, wq, ws, qmax: int = 127):
    """Dynamic-activation int8 dot: a (B, K) f32 x wq (OUT, K) int8 with
    per-output scales ws (OUT, 1) -> (B, OUT) f32."""
    qa, sa = rowwise_quant(jnp.asarray(a, F32), qmax)
    acc = lax.dot_general(qa, wq, _CONTRACT_LAST,
                          preferred_element_type=I32)
    return acc.astype(F32) * sa * jnp.asarray(ws, F32).T


def lstm_scan_q_ref(kpms, wxq, wxs, whq, whs, b, qmax: int = 127):
    """int8 oracle of :func:`..kernel.lstm_scan_q` (same weight layout)."""
    kpms = jnp.asarray(kpms, F32)
    b = jnp.asarray(b, F32)
    h0 = jnp.zeros((kpms.shape[0], whq.shape[1]), F32)

    def cell(carry, x_t):
        h, c = carry
        z = qdot_ref(x_t, wxq, wxs, qmax) + qdot_ref(h, whq, whs, qmax) + b
        h, c = _gates(z, c)
        return (h, c), None

    (h, _), _ = lax.scan(cell, (h0, jnp.zeros_like(h0)),
                         kpms.transpose(1, 0, 2))
    return h
