"""Rowwise symmetric int8 quantisation as a Pallas kernel.

Used at the split boundary (core/boundary codec), for compressed gradient
all-reduce (optim/compress), and the int8 KV-cache option. One (block_rows,
d) tile per grid step; absmax + scale + round happen entirely in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _quant_kernel(x_ref, q_ref, s_ref, *, qmax):
    x = x_ref[...].astype(F32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    # explicit reciprocal multiply: XLA rewrites `amax / const` that way in
    # some fusion contexts but not others; writing it out keeps the kernel
    # and the jnp oracle bit-identical (a 1-ULP scale skew flips round())
    scale = jnp.maximum(amax, 1e-8) * jnp.float32(1.0 / qmax)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quantize(x, *, qmax: int = 127, block_rows: int = 256,
             interpret: bool = True):
    """x: (n, d) -> (q int8 (n, d), scale f32 (n, 1))."""
    n, d = x.shape
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    npad = x.shape[0]
    kernel = functools.partial(_quant_kernel, qmax=qmax)
    q, s = pl.pallas_call(
        kernel,
        grid=(npad // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, d), jnp.int8),
            jax.ShapeDtypeStruct((npad, 1), F32),
        ],
        interpret=interpret,
    )(x)
    return q[:n], s[:n]
