from repro.kernels.quant.kernel import quantize  # noqa: F401
from repro.kernels.quant.ops import dequantize_rows, quantize_rows  # noqa: F401
from repro.kernels.quant.ref import dequantize_ref, quantize_ref  # noqa: F401
