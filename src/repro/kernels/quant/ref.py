"""jnp oracle for the int8 quantisation kernel."""
import jax.numpy as jnp

F32 = jnp.float32


def quantize_ref(x, qmax: int = 127):
    """Rowwise symmetric int8: x (..., d) -> (q int8, scale (..., 1) f32)."""
    amax = jnp.max(jnp.abs(x.astype(F32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -qmax - 1, qmax)
    return q.astype(jnp.int8), scale


def dequantize_ref(q, scale, dtype=jnp.bfloat16):
    return (q.astype(F32) * scale).astype(dtype)
