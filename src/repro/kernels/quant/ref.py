"""jnp oracle for the int8 quantisation kernel.

One formula, one home: the rowwise symmetric quantiser lives in
repro.core.boundary (incl. the reciprocal-multiply scale that keeps it
bit-identical with the Pallas kernel); this module re-exports it under
the kernel-reference naming convention.
"""
import jax.numpy as jnp

from repro.core.boundary import dequantize as _dequantize
from repro.core.boundary import rowwise_quant


def quantize_ref(x, qmax: int = 127):
    """Rowwise symmetric int8: x (..., d) -> (q int8, scale (..., 1) f32)."""
    return rowwise_quant(x, qmax)


def dequantize_ref(q, scale, dtype=jnp.bfloat16):
    return _dequantize(q, scale, dtype)
