"""jit wrappers for quantise/dequantise."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quant.kernel import quantize
from repro.kernels.quant.ref import dequantize_ref, quantize_ref


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def quantize_rows(x, *, use_kernel=True, interpret=True):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if use_kernel:
        q, s = quantize(x2, interpret=interpret)
    else:
        q, s = quantize_ref(x2)
    return q.reshape(shape), s.reshape(shape[:-1] + (1,))


@partial(jax.jit, static_argnames=("dtype",))
def dequantize_rows(q, s, dtype=jnp.bfloat16):
    return dequantize_ref(q, s, dtype)
