"""Mamba2 SSD as a Pallas TPU kernel.

Grid (B*G, nc): the chunk axis is innermost/sequential, so the running
inter-chunk state (hpg, hd, N) lives in VMEM scratch across chunk steps —
the XLA fallback materialises every chunk's (L, L) decay matrices in HBM
(the 23GB temp observed on mamba2 train_4k); here one (L, L) tile exists
per head-group at a time, in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, sout_ref, s_ref,
                *, n_chunks, hpg, hd, N, L):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[...].astype(F32)  # (L, hpg, hd)
    dt = dt_ref[...].astype(F32)  # (L, hpg)
    A = a_ref[...].astype(F32)  # (hpg,)
    Bv = b_ref[...].astype(F32)  # (L, N)
    Cv = c_ref[...].astype(F32)  # (L, N)

    dA = dt * A[None]  # (L, hpg)
    lcum = jnp.cumsum(dA, axis=0)
    CB = jax.lax.dot_general(Cv, Bv, (((1,), (1,)), ((), ())),
                             preferred_element_type=F32)  # (L, L) [t,s]
    decay = lcum[:, None, :] - lcum[None, :, :]  # (t, s, hpg)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= (
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    # mask before exp (matches ref.py): keeps the masked upper triangle
    # from overflowing exp and poisoning gradients through the where().
    M = jnp.exp(jnp.where(tri[..., None], decay, -jnp.inf)) * CB[..., None]
    du = dt[:, :, None] * x  # (L, hpg, hd)
    y_intra = jnp.einsum("tsh,shd->thd", M, du, preferred_element_type=F32)
    # inter-chunk: contribution of the carried state
    qdecay = jnp.exp(lcum)  # (L, hpg)
    s_prev = s_ref[...]  # (hpg, hd, N)
    y_inter = jnp.einsum("tn,hdn->thd", Cv, s_prev,
                         preferred_element_type=F32) * qdecay[:, :, None]
    y_ref[...] = (y_intra + y_inter).astype(y_ref.dtype)
    # state update
    lend = lcum[-1]  # (hpg,)
    sdecay = jnp.exp(lend[None] - lcum)  # (L, hpg)
    S_c = jnp.einsum("tn,thd->hdn", Bv, du * sdecay[:, :, None],
                     preferred_element_type=F32)
    s_ref[...] = s_prev * jnp.exp(lend)[:, None, None] + S_c

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        sout_ref[...] = s_ref[...]


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 256, interpret: bool = True):
    """x: (B,S,nh,hd); dt: (B,S,nh); A: (nh,); Bm/Cm: (B,S,G,N).
    Returns (y (B,S,nh,hd), state (B,G,hpg,hd,N))."""
    B, S, nh, hd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = nh // G
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L
    # regroup to (B*G, S, hpg, ...) so one grid cell owns one B/C group
    xg = x.reshape(B, S, G, hpg, hd).transpose(0, 2, 1, 3, 4).reshape(
        B * G, S, hpg, hd)
    dtg = dt.reshape(B, S, G, hpg).transpose(0, 2, 1, 3).reshape(
        B * G, S, hpg)
    Ag = A.reshape(G, hpg)
    Ag = jnp.broadcast_to(Ag[None], (B, G, hpg)).reshape(B * G, hpg)
    Bg = Bm.transpose(0, 2, 1, 3).reshape(B * G, S, N)
    Cg = Cm.transpose(0, 2, 1, 3).reshape(B * G, S, N)
    kernel = functools.partial(_ssd_kernel, n_chunks=nc, hpg=hpg, hd=hd,
                               N=N, L=L)
    y, state = pl.pallas_call(
        kernel,
        grid=(B * G, nc),
        in_specs=[
            pl.BlockSpec((None, L, hpg, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, L, hpg), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, hpg), lambda b, c: (b, 0)),
            pl.BlockSpec((None, L, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, L, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, L, hpg, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, hpg, hd, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * G, S, hpg, hd), x.dtype),
            jax.ShapeDtypeStruct((B * G, hpg, hd, N), F32),
        ],
        scratch_shapes=[pltpu.VMEM((hpg, hd, N), F32)],
        interpret=interpret,
    )(xg, dtg, Ag, Bg, Cg)
    y = y.reshape(B, G, S, hpg, hd).transpose(0, 2, 1, 3, 4).reshape(
        B, S, nh, hd)
    state = state.reshape(B, G, hpg, hd, N)
    return y, state
