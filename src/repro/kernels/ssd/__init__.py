from repro.kernels.ssd.kernel import ssd  # noqa: F401
from repro.kernels.ssd.ops import ssd_mixer  # noqa: F401
from repro.kernels.ssd.ref import ssd_ref  # noqa: F401
