from repro.kernels.ssd.kernel import ssd  # noqa: F401
from repro.kernels.ssd.ops import ssd_mixer, ssd_step  # noqa: F401
from repro.kernels.ssd.ref import ssd_ref, ssd_step_ref  # noqa: F401
