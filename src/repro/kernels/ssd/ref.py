"""jnp oracle for the Mamba2 SSD (state-space dual) chunk kernel.

Inputs are the post-projection, post-conv tensors of one sequence:
  x  (B, S, nh, hd)   value-like stream
  dt (B, S, nh)       softplus-discretised step sizes
  A  (nh,)            negative per-head decay rates
  Bm (B, S, G, N)     input-expansion vectors (ngroups G)
  Cm (B, S, G, N)     output-contraction vectors
Output: y (B, S, nh, hd) and final state (B, G, nh//G, hd, N).
"""
from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def ssd_ref(x, dt, A, Bm, Cm, chunk: int):
    B, S, nh, hd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = nh // G
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L
    xc = x.astype(F32).reshape(B, nc, L, G, hpg, hd)
    dtc = dt.astype(F32).reshape(B, nc, L, nh)
    Bc = Bm.astype(F32).reshape(B, nc, L, G, N)
    Cc = Cm.astype(F32).reshape(B, nc, L, G, N)
    dA = dtc * A.astype(F32)
    lcum = jnp.cumsum(dA, axis=2)  # (B,nc,L,nh)
    CB = jnp.einsum("bclgn,bcmgn->bcglm", Cc, Bc)
    decay = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]  # (B,nc,t,s,nh)
    decay = jnp.transpose(decay, (0, 1, 4, 2, 3))
    tri = jnp.tril(jnp.ones((L, L), bool))
    M = jnp.where(tri, jnp.exp(decay), 0.0).reshape(B, nc, G, hpg, L, L)
    M = M * CB[:, :, :, None]
    du = dtc.reshape(B, nc, L, G, hpg)[..., None] * xc
    y_intra = jnp.einsum("bcghts,bcsghd->bctghd", M, du)
    lend = lcum[:, :, -1:, :]
    sdecay = jnp.exp(lend - lcum).reshape(B, nc, L, G, hpg)
    S_c = jnp.einsum("bcsgn,bcsghd->bcghdn", Bc, du * sdecay[..., None])
    cd = jnp.exp(lend[:, :, 0]).reshape(B, nc, G, hpg)
    states = [jnp.zeros((B, G, hpg, hd, N), F32)]
    for c in range(nc):
        states.append(states[-1] * cd[:, c][..., None, None] + S_c[:, c])
    s_prev = jnp.stack(states[:-1], axis=1)  # (B,nc,G,hpg,hd,N)
    qdecay = jnp.exp(lcum).reshape(B, nc, L, G, hpg)
    y_inter = jnp.einsum("bctgn,bcghdn->bctghd", Cc, s_prev) * qdecay[..., None]
    y = (y_intra + y_inter).reshape(B, S, nh, hd)
    return y.astype(x.dtype), states[-1]
