"""jnp oracle for the Mamba2 SSD (state-space dual) chunk kernel.

Inputs are the post-projection, post-conv tensors of one sequence:
  x  (B, S, nh, hd)   value-like stream
  dt (B, S, nh)       softplus-discretised step sizes
  A  (nh,)            negative per-head decay rates
  Bm (B, S, G, N)     input-expansion vectors (ngroups G)
  Cm (B, S, G, N)     output-contraction vectors
Output: y (B, S, nh, hd) and final state (B, G, nh//G, hd, N).

``ssd_step_ref`` is the same recurrence specialised to one timestep with
an explicit carried state — the O(1) ingest form a recurrent estimator
serves (``repro.estimator.ssm``): scanning it over S steps from a zero
state reproduces ``ssd_ref``'s outputs and final state (pinned by
``tests/test_kernels.py``).
"""
from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def ssd_step_ref(x_t, dt_t, A, B_t, C_t, state):
    """One SSD recurrence step: S' = exp(dt*A) * S + dt * (B (x) x).

    ``x_t`` (B, nh, hd); ``dt_t`` (B, nh); ``A`` (nh,); ``B_t``/``C_t``
    (B, G, N); ``state`` (B, G, nh//G, hd, N) — the chunk kernel's
    carried-state layout, so a sequence pass's final state resumes here
    directly. Returns (y_t (B, nh, hd), new state)."""
    b, nh, hd = x_t.shape
    G, N = B_t.shape[1], B_t.shape[2]
    hpg = nh // G
    dA = (dt_t.astype(F32) * A.astype(F32)).reshape(b, G, hpg)
    du = (dt_t.astype(F32)[..., None] * x_t.astype(F32)
          ).reshape(b, G, hpg, hd)
    state = (state.astype(F32) * jnp.exp(dA)[..., None, None]
             + jnp.einsum("bgn,bghd->bghdn", B_t.astype(F32), du))
    y = jnp.einsum("bgn,bghdn->bghd", C_t.astype(F32), state)
    return y.reshape(b, nh, hd).astype(x_t.dtype), state


def ssd_ref(x, dt, A, Bm, Cm, chunk: int):
    B, S, nh, hd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = nh // G
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L
    xc = x.astype(F32).reshape(B, nc, L, G, hpg, hd)
    dtc = dt.astype(F32).reshape(B, nc, L, nh)
    Bc = Bm.astype(F32).reshape(B, nc, L, G, N)
    Cc = Cm.astype(F32).reshape(B, nc, L, G, N)
    dA = dtc * A.astype(F32)
    lcum = jnp.cumsum(dA, axis=2)  # (B,nc,L,nh)
    CB = jnp.einsum("bclgn,bcmgn->bcglm", Cc, Bc)
    decay = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]  # (B,nc,t,s,nh)
    decay = jnp.transpose(decay, (0, 1, 4, 2, 3))
    tri = jnp.tril(jnp.ones((L, L), bool))
    # mask BEFORE exp: the upper triangle holds +(lcum[s]-lcum[t]) which
    # overflows exp once training grows dt, and inf in the discarded
    # branch of a where() poisons the backward pass (inf * 0 = nan).
    # exp(-inf) = 0 keeps both the value and the gradient finite.
    M = jnp.exp(jnp.where(tri, decay, -jnp.inf)
                ).reshape(B, nc, G, hpg, L, L)
    M = M * CB[:, :, :, None]
    du = dtc.reshape(B, nc, L, G, hpg)[..., None] * xc
    y_intra = jnp.einsum("bcghts,bcsghd->bctghd", M, du)
    lend = lcum[:, :, -1:, :]
    sdecay = jnp.exp(lend - lcum).reshape(B, nc, L, G, hpg)
    S_c = jnp.einsum("bcsgn,bcsghd->bcghdn", Bc, du * sdecay[..., None])
    cd = jnp.exp(lend[:, :, 0]).reshape(B, nc, G, hpg)
    states = [jnp.zeros((B, G, hpg, hd, N), F32)]
    for c in range(nc):
        states.append(states[-1] * cd[:, c][..., None, None] + S_c[:, c])
    s_prev = jnp.stack(states[:-1], axis=1)  # (B,nc,G,hpg,hd,N)
    qdecay = jnp.exp(lcum).reshape(B, nc, L, G, hpg)
    y_inter = jnp.einsum("bctgn,bcghdn->bctghd", Cc, s_prev) * qdecay[..., None]
    y = (y_intra + y_inter).reshape(B, S, nh, hd)
    return y.astype(x.dtype), states[-1]
