"""jit wrapper for the SSD kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd.kernel import ssd
from repro.kernels.ssd.ref import ssd_ref


@partial(jax.jit, static_argnames=("chunk", "use_kernel", "interpret"))
def ssd_mixer(x, dt, A, Bm, Cm, *, chunk=256, use_kernel=True,
              interpret=True):
    if use_kernel:
        return ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
    return ssd_ref(x, dt, A, Bm, Cm, chunk)
