"""jit wrappers for the SSD kernel and its single-step recurrence."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd.kernel import ssd
from repro.kernels.ssd.ref import ssd_ref, ssd_step_ref


@partial(jax.jit, static_argnames=("chunk", "use_kernel", "interpret"))
def ssd_mixer(x, dt, A, Bm, Cm, *, chunk=256, use_kernel=True,
              interpret=True):
    if use_kernel:
        return ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
    return ssd_ref(x, dt, A, Bm, Cm, chunk)


@jax.jit
def ssd_step(x_t, dt_t, A, B_t, C_t, state):
    """One O(1) SSD recurrence step (see ``ssd_step_ref``).

    Pure jnp — a single step has no tile structure worth a Pallas kernel,
    and keeping it GSPMD-partitionable is what lets the recurrent
    estimator's per-report ingest shard over a serving mesh
    (``pallas_call`` cannot be partitioned; the chunked ``ssd`` kernel is
    for offline/sequence passes)."""
    return ssd_step_ref(x_t, dt_t, A, B_t, C_t, state)
