"""jnp oracle for the pairwise-distance kernel (dCor hot spot).

One formula, one home: the oracle lives in repro.core.privacy (incl. the
exact-zero self-distance diagonal pin); this module just re-exports it
under the kernel-reference naming convention.
"""
from repro.core.privacy import pairwise_dists as pairwise_dists_ref  # noqa: F401
