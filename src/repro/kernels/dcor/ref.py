"""jnp oracle for the pairwise-distance kernel (dCor hot spot)."""
import jax.numpy as jnp

F32 = jnp.float32


def pairwise_dists_ref(x):
    """x: (n, d) -> (n, n) Euclidean distances."""
    x = x.astype(F32)
    sq = jnp.sum(x * x, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return jnp.sqrt(jnp.maximum(d2, 0.0))
