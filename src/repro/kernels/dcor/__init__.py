from repro.kernels.dcor.kernel import pairwise_dists  # noqa: F401
from repro.kernels.dcor.ops import dcor_kernel  # noqa: F401
from repro.kernels.dcor.ref import pairwise_dists_ref  # noqa: F401
