"""jit wrapper: dCor with the Pallas pairwise-distance kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.core.privacy import dcor as _dcor
from repro.kernels.dcor.kernel import pairwise_dists


@partial(jax.jit, static_argnames=("interpret",))
def dcor_kernel(x, y, *, interpret: bool = True):
    fn = partial(pairwise_dists, interpret=interpret)
    return _dcor(x, y, dist_fn=fn)
