"""Pairwise Euclidean distances as a Pallas kernel — the O(n^2 d) inner loop
of distance correlation (privacy metric, Sec. V).

Grid (ni, nj, nd): (block_n, block_d) tiles of rows i and j are streamed
through VMEM; squared distances accumulate in an fp32 scratch across the
feature-chunk axis (innermost, sequential), and the sqrt happens on the
final chunk. Feature dim never materialises in full — this is what lets
dCor run over multi-megabyte activations on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _dist_kernel(xi_ref, xj_ref, o_ref, acc_ref, *, n_d):
    kd = pl.program_id(2)
    bi, bj = pl.program_id(0), pl.program_id(1)

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xi = xi_ref[...].astype(F32)  # (bn, bd)
    xj = xj_ref[...].astype(F32)
    si = jnp.sum(xi * xi, axis=1)
    sj = jnp.sum(xj * xj, axis=1)
    cross = jax.lax.dot_general(xi, xj, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32)
    acc_ref[...] += si[:, None] + sj[None, :] - 2.0 * cross

    @pl.when(kd == n_d - 1)
    def _finish():
        # pin self-distances to exact 0: the squared-norm expansion cancels
        # catastrophically on the diagonal and sqrt amplifies the residue
        bn = acc_ref.shape[0]
        rows = bi * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0)
        cols = bj * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1)
        d2 = jnp.where(rows == cols, 0.0, jnp.maximum(acc_ref[...], 0.0))
        o_ref[...] = jnp.sqrt(d2).astype(o_ref.dtype)


def pairwise_dists(x, *, block_n: int = 128, block_d: int = 512,
                   interpret: bool = True):
    n, d = x.shape
    block_n = min(block_n, n)
    block_d = min(block_d, d)
    pad_n = (-n) % block_n
    pad_d = (-d) % block_d
    if pad_n or pad_d:
        x = jnp.pad(x, ((0, pad_n), (0, pad_d)))
    np_, dp = x.shape
    grid = (np_ // block_n, np_ // block_n, dp // block_d)
    kernel = functools.partial(_dist_kernel, n_d=grid[2])
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_n, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), F32),
        scratch_shapes=[pltpu.VMEM((block_n, block_n), F32)],
        interpret=interpret,
    )(x, x)
    return out[:n, :n]
