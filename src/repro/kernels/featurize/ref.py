"""jnp oracle for the fused KPM featurize kernel.

Same contract as the numpy host path (``EpisodeBatch.kpm_windows``):
window ``b`` of the output covers raw trace steps ``[b, b + window)``,
normalized by the fixed affine of ``channel.kpm``. Pure gather + affine,
so it runs anywhere jnp does.
"""
from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def featurize_ref(kpms, center, scale, window: int):
    """kpms (N, L, K) raw -> (N, L - window + 1, window, K) normalized."""
    x = (jnp.asarray(kpms).astype(F32) - jnp.asarray(center, F32)) \
        / jnp.asarray(scale, F32)
    b = x.shape[1] - window + 1
    idx = jnp.arange(b)[:, None] + jnp.arange(window)[None, :]
    return x[:, idx]  # (N, B, window, K) one gather
