from repro.kernels.featurize.kernel import featurize  # noqa: F401
from repro.kernels.featurize.ops import kpm_feature_windows  # noqa: F401
from repro.kernels.featurize.ref import featurize_ref  # noqa: F401
