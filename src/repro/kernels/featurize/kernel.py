"""Fused KPM-window featurize + normalize as a Pallas kernel.

The fleet estimator consumes, per report period, each UE's rolling
(WINDOW, 15) KPM window. The host path materializes every window up
front (``EpisodeBatch.kpm_windows``: a numpy stride-trick view whose
``astype(float32)`` copy expands the (N, T + W, 15) trace ~WINDOWx), then
ships the copies to the device chunk by chunk. This kernel fuses the
whole featurize stage on device: one pass over a raw KPM slab normalizes
(the fixed affine of ``channel.kpm``) and scatters the overlapping
windows straight into VMEM-tiled output blocks — the trace crosses the
host->device boundary once, at 1/WINDOW the bytes.

Grid: (row blocks, window blocks). Every grid step sees the full trace
axis (the overlapping windows make block-aligned input tiling impossible)
and slices its windows out with dynamic starts; the window axis itself is
a static WINDOW-step unroll of contiguous copies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _featurize_kernel(x_ref, c_ref, s_ref, o_ref, *, block_b, window):
    j = pl.program_id(1)
    # normalize once per grid step; the division (not a reciprocal
    # multiply) mirrors channel.kpm.normalize_kpms so kernel, oracle and
    # host path agree to f32 rounding
    xn = (x_ref[...].astype(F32) - c_ref[...]) / s_ref[...]
    for w in range(window):  # static unroll: WINDOW contiguous copies
        o_ref[:, :, w, :] = jax.lax.dynamic_slice_in_dim(
            xn, j * block_b + w, block_b, axis=1)


def featurize(kpms, center, scale, window: int, *, block_rows: int = 128,
              block_windows: int = 32, interpret: bool = True):
    """kpms (N, L, K) raw -> (N, B, window, K) normalized windows, where
    ``B = L - window + 1`` and window ``b`` covers trace steps
    ``[b, b + window)`` — the ``EpisodeBatch.kpm_windows`` convention."""
    n, length, k = kpms.shape
    b = length - window + 1
    if b < 1:
        raise ValueError(f"trace of {length} steps holds no {window}-window")
    bn = min(block_rows, n)
    bb = min(block_windows, b)
    pad_n, pad_b = (-n) % bn, (-b) % bb
    if pad_n or pad_b:  # pad rows + trace tail; padded windows are sliced off
        kpms = jnp.pad(kpms, ((0, pad_n), (0, pad_b), (0, 0)))
    npad, bpad = n + pad_n, b + pad_b
    kernel = functools.partial(_featurize_kernel, block_b=bb, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(npad // bn, bpad // bb),
        in_specs=[
            # full trace axis per step: the windows overlap, so their
            # source range is not block-alignable — each step dynamic-
            # slices its own span out of the shared slab
            pl.BlockSpec((bn, length + pad_b, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i, j: (0, 0)),
            pl.BlockSpec((1, k), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bb, window, k),
                               lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, bpad, window, k), F32),
        interpret=interpret,
    )(kpms, jnp.asarray(center, F32).reshape(1, k),
      jnp.asarray(scale, F32).reshape(1, k))
    return out[:n, :b]
