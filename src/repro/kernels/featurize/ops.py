"""jit dispatch for the fused KPM-window featurize stage."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.featurize.kernel import featurize
from repro.kernels.featurize.ref import featurize_ref


@partial(jax.jit, static_argnames=("window", "use_kernel", "interpret"))
def kpm_feature_windows(kpms, center, scale, window: int, *,
                        use_kernel: bool = True, interpret: bool = True):
    """(N, L, K) raw KPM slab -> (N, L - window + 1, window, K) normalized
    rolling windows, entirely on device.

    Drop-in for the ``EpisodeBatch.kpm_windows(normalize=True)`` host path
    over any trace slab: the engine's chunked ``estimate_fleet`` feeds the
    slab covering one chunk of report periods and reshapes the result into
    estimator rows. ``use_kernel=False`` runs the jnp oracle (one fused
    gather + affine — also what GSPMD shards under a mesh)."""
    if use_kernel:
        return featurize(kpms, center, scale, window, interpret=interpret)
    return featurize_ref(kpms, center, scale, window)
