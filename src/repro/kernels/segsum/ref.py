"""jnp oracle for the batched segment reduction kernel.

``jax.ops.segment_{sum,max}`` vmapped over the batch axis — the exact
ops the scheduler normalizers and cell-load aggregation call today, so
an allclose pin against this ref is an allclose pin against the engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def segment_reduce_ref(values, seg_ids, n_segments: int, *,
                       op: str = "sum"):
    """values (T, N) + seg_ids (T, N) -> (T, C) per-batch reductions."""
    if op == "sum":
        fn = lambda v, g: jax.ops.segment_sum(v, g, num_segments=n_segments)
    elif op == "max":
        fn = lambda v, g: jax.ops.segment_max(v, g, num_segments=n_segments)
    else:
        raise ValueError(f"op must be 'sum' or 'max': {op!r}")
    return jax.vmap(fn)(values.astype(F32), seg_ids)
