"""Masked batched segment reduction (sum / max) as a Pallas kernel.

The multi-cell fleet leans on tiny-segment reductions in two hot places:
the gNB PRB scheduler normalizers (``sim.sched.cell_shares`` and the
max-C/I winner pick, every report period inside the engine's scan) and
the (C, T) per-cell offered-load aggregation behind the inter-cell
interference coupling (``sim.cells.cell_load``). XLA lowers
``segment_sum`` to scatter-adds; here the reduction runs as a one-hot
compare-and-reduce over VMEM tiles — no scatter, and the C axis (cells,
typically < 64) stays resident.

Out-of-range segment ids contribute nothing, which is the whole masking
story: masked rows (empty pool slots) are redirected to segment id
``n_segments`` by the ops wrapper and fall out of the one-hot compare.

Grid: (batch tiles, element tiles); the element axis is innermost and
accumulates into a (block_t, C) scratch across its tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
I32 = jnp.int32

NEG_INF = float("-inf")


def _segreduce_kernel(v_ref, g_ref, o_ref, acc_ref, *, n_segments, nk, op):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.full_like(
            acc_ref, 0.0 if op == "sum" else NEG_INF)

    v = v_ref[...].astype(F32)[:, :, None]  # (bt, bn, 1)
    # broadcasted_iota: a 1-D iota does not lower on TPU
    seg = jax.lax.broadcasted_iota(I32, (1, 1, n_segments), 2)
    hit = g_ref[...][:, :, None] == seg  # (bt, bn, C) one-hot
    if op == "sum":
        acc_ref[...] += jnp.sum(jnp.where(hit, v, 0.0), axis=1)
    else:
        acc_ref[...] = jnp.maximum(
            acc_ref[...], jnp.max(jnp.where(hit, v, NEG_INF), axis=1))

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[...] = acc_ref[...]


def segment_reduce_batched(values, seg_ids, n_segments: int, *,
                           op: str = "sum", block_t: int = 8,
                           block_n: int = 512, interpret: bool = True):
    """values (T, N) f32 + seg_ids (T, N) i32 -> (T, C) reductions.

    ``op``: "sum" or "max". Segment ids outside ``[0, n_segments)`` are
    ignored; an empty segment reduces to the identity (0 for sum, -inf
    for max — matching ``jax.ops.segment_{sum,max}``)."""
    if op not in ("sum", "max"):
        raise ValueError(f"op must be 'sum' or 'max': {op!r}")
    t, n = values.shape
    bt, bn = min(block_t, t), min(block_n, n)
    pt, pn = (-t) % bt, (-n) % bn
    if pt or pn:
        values = jnp.pad(values, ((0, pt), (0, pn)))
        # padded ids hit no segment of [0, C)
        seg_ids = jnp.pad(seg_ids, ((0, pt), (0, pn)),
                          constant_values=n_segments)
    tp, npad = t + pt, n + pn
    nk = npad // bn
    out = pl.pallas_call(
        functools.partial(_segreduce_kernel, n_segments=n_segments, nk=nk,
                          op=op),
        grid=(tp // bt, nk),
        in_specs=[
            pl.BlockSpec((bt, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bt, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bt, n_segments), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, n_segments), F32),
        scratch_shapes=[pltpu.VMEM((bt, n_segments), F32)],
        interpret=interpret,
    )(values.astype(F32), seg_ids.astype(I32))
    return out[:t]
