from repro.kernels.segsum.kernel import segment_reduce_batched  # noqa: F401
from repro.kernels.segsum.ops import segment_reduce  # noqa: F401
from repro.kernels.segsum.ref import segment_reduce_ref  # noqa: F401
