"""jit dispatch for masked segment reductions.

``segment_reduce`` is the shared server for both PRB scheduler
normalizers (``sim.sched``) and the per-cell load aggregation
(``sim.cells``): it accepts 1-D or batched 2-D inputs, folds an optional
activity mask into the out-of-range-id redirect (the same dummy-segment
idiom ``scheduler_step`` uses), and dispatches kernel vs jnp oracle.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.segsum.kernel import segment_reduce_batched
from repro.kernels.segsum.ref import segment_reduce_ref


@partial(jax.jit,
         static_argnames=("n_segments", "op", "use_kernel", "interpret"))
def segment_reduce(values, seg_ids, n_segments: int, *, op: str = "sum",
                   mask=None, use_kernel: bool = True,
                   interpret: bool = True):
    """Reduce ``values`` into ``n_segments`` buckets keyed by ``seg_ids``.

    Accepts (N,) or (T, N) inputs (``seg_ids`` broadcasts against
    ``values``). ``mask=False`` rows are redirected to segment id
    ``n_segments`` and so contribute nothing. Empty segments reduce to
    the op identity (0 for sum, -inf for max), matching
    ``jax.ops.segment_{sum,max}``."""
    squeeze = values.ndim == 1
    v = values[None] if squeeze else values
    g = jnp.broadcast_to(jnp.asarray(seg_ids, jnp.int32), v.shape)
    if mask is not None:
        m = jnp.broadcast_to(jnp.asarray(mask, bool), v.shape)
        g = jnp.where(m, g, n_segments)
    if use_kernel:
        out = segment_reduce_batched(v, g, n_segments, op=op,
                                     interpret=interpret)
    else:
        # one spill bucket so dummy-redirected ids (== n_segments) stay
        # in range for the jnp scatter path, then drop it
        out = segment_reduce_ref(v, g, n_segments + 1,
                                 op=op)[:, :n_segments]
    return out[0] if squeeze else out
