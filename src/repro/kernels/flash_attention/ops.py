"""jit'd public wrapper: GQA-aware flash attention entry point.

Differentiable: forward runs the Pallas kernel; backward differentiates
through the jnp oracle (mathematically identical) via custom_vjp — the
standard bring-up pattern until the dedicated backward kernel lands.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

_VJP_CACHE: dict = {}


def _kernel_attn(causal, window, block_q, block_k, interpret):
    key = (causal, window, block_q, block_k, interpret)
    if key in _VJP_CACHE:
        return _VJP_CACHE[key]

    @jax.custom_vjp
    def f(q, k, v):
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda a, b, c: attention_ref(a, b, c, causal=causal,
                                          window=window), q, k, v)
        return vjp(g)

    f.defvjp(fwd, bwd)
    _VJP_CACHE[key] = f
    return f


def _flatten_gqa(q, k, v):
    """(B,S,H,dh) + (B,S,KV,dh) -> (B*H, S, dh) with kv broadcast."""
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * H, k.shape[1], dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * H, v.shape[1], dh)
    return qf, kf, vf


@partial(jax.jit, static_argnames=("causal", "window", "use_kernel",
                                   "block_q", "block_k", "interpret"))
def mha(q, k, v, *, causal=True, window=0, use_kernel=True, block_q=128,
        block_k=128, interpret=True):
    """Multi-head attention. q: (B,S,H,dh); k,v: (B,S,KV,dh) (GQA)."""
    B, Sq, H, dh = q.shape
    qf, kf, vf = _flatten_gqa(q, k, v)
    if use_kernel:
        of = _kernel_attn(causal, window, block_q, block_k, interpret)(
            qf, kf, vf)
    else:
        of = attention_ref(qf, kf, vf, causal=causal, window=window)
    return of.reshape(B, H, Sq, dh).transpose(0, 2, 1, 3)
