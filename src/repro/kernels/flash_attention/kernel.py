"""Flash attention as a Pallas TPU kernel.

Tiling: grid (BH, num_q_blocks, num_kv_blocks); the kv-block axis is the
innermost (sequential on TPU), so fp32 scratch accumulators (acc, m, l) in
VMEM persist across kv steps — the classical online-softmax recurrence.
BlockSpecs keep one (block_q, dh) Q tile and one (block_k, dh) K/V tile in
VMEM; dh and block sizes should be multiples of 128 on real hardware (MXU
alignment) — asserted softly so reduced test shapes still run in interpret
mode on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, window, block_q, block_k, n_kv):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(F32)  # (bq, dh)
    k = k_ref[...].astype(F32)  # (bk, dh)
    v = v_ref[...].astype(F32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    valid = jnp.ones((block_q, block_k), bool)
    if causal:
        valid &= q_pos >= k_pos
    if window:
        valid &= q_pos - k_pos < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=F32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)[:, None]
                      ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q,k,v: (BH, S, dh) with kv heads pre-broadcast to q heads."""
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else dh**-0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    n_q = Sq // block_q
    n_kv = Sk // block_k
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[
            # fp32 accumulators surviving the (sequential) kv-block loop
            pltpu.VMEM((block_q, dh), F32),
            pltpu.VMEM((block_q,), F32),
            pltpu.VMEM((block_q,), F32),
        ],
        interpret=interpret,
    )(q, k, v)
