"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """q,k,v: (BH, S, dh) (kv heads pre-broadcast). Returns (BH, S, dh)."""
    _, Sq, dh = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else dh**-0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(F32), k.astype(F32)) * scale
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= qp - kp < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(F32)).astype(q.dtype)
