"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships three files: kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd public wrapper), ref.py (pure-jnp oracle). On this
CPU container they execute via interpret=True; on TPU set interpret=False.

  flash_attention  tiled online-softmax attention (causal / SWA / GQA)
  dcor             pairwise-distance tiles for distance correlation
  ssd              Mamba2 state-space-dual chunk scan (VMEM-resident state)
  quant            rowwise symmetric int8 quantisation
"""
from repro.kernels import dcor, flash_attention, quant, ssd  # noqa: F401
