"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships three files: kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd public wrapper), ref.py (pure-jnp oracle). On this
CPU container they execute via interpret=True; on TPU set interpret=False.

  flash_attention  tiled online-softmax attention (causal / SWA / GQA)
  dcor             pairwise-distance tiles for distance correlation
  ssd              Mamba2 state-space-dual chunk scan (VMEM-resident state)
  quant            rowwise symmetric int8 quantisation
  featurize        fused KPM window extraction + normalisation
  lstm             fused LSTM-cell scan (fp32 and int8 serving variants)
  qmm              int8 x int8 -> int32 rowwise-scaled serving matmul
  segsum           masked batched segment reduction (sum / max)
"""
from repro.kernels import (dcor, featurize, flash_attention, lstm,  # noqa: F401
                           qmm, quant, segsum, ssd)
