"""jit dispatch for int8 serving matmuls.

``quantize_weight`` is the offline half (done once per deployment);
``int8_matmul`` the serving half — dynamic rowwise activation
quantization (via the ``kernels/quant`` oracle formula, inside the same
jit) followed by the int8 x int8 -> int32 product.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.qmm.kernel import qmm
from repro.kernels.qmm.ref import qmm_ref
from repro.kernels.quant.ops import quantize_rows
from repro.kernels.quant.ref import quantize_ref


def quantize_weight(w, *, use_kernel: bool = True, interpret: bool = True):
    """(K, N) f32 weight -> ((N, K) int8, (N, 1) f32 scales).

    Rowwise quantization of ``w.T`` — one int8 row (and one scale) per
    *output* channel, the layout ``int8_matmul`` and the int8 LSTM kernel
    consume. Runs the existing ``kernels/quant`` quantizer."""
    return quantize_rows(w.T, use_kernel=use_kernel, interpret=interpret)


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def int8_matmul(x, wq, sw, *, use_kernel: bool = True,
                interpret: bool = True):
    """x (M, K) f32 @ quantized weight -> (M, N) f32.

    Activations are quantized rowwise on the fly (exactly the
    ``kernels/quant`` formula, so the quant kernel and this path agree
    bit-for-bit); the product runs as int8 x int8 -> int32 and is scaled
    back to f32. ``use_kernel=False`` takes the jnp oracle — identical
    numerics (integer accumulation is exact), and the form GSPMD can
    shard under a serving mesh."""
    xq, sx = quantize_ref(x)
    if use_kernel:
        return qmm(xq, sx, wq, sw, interpret=interpret)
    return qmm_ref(xq, sx, wq, sw)
