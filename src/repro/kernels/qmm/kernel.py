"""int8 x int8 -> int32 rowwise-scaled matmul as a Pallas kernel.

The int8 serving path's FC layers (estimator LSTM projection, CNN fc,
regression head): activations quantized rowwise per sample, weights
pre-quantized rowwise per output channel (both via the ``kernels/quant``
formula), the product accumulated on the MXU in int32 and scaled back to
f32 on the final K tile. Integer accumulation is associative, so the
tiled kernel and the one-shot jnp oracle agree *exactly* — the kernel-
vs-ref pin is ``assert_array_equal``, not allclose.

Grid: (M tiles, N tiles, K tiles); K innermost (sequential on TPU) with
the int32 accumulator living in VMEM scratch across K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
I32 = jnp.int32

_CONTRACT_LAST = (((1,), (1,)), ((), ()))


def _qmm_kernel(xq_ref, sx_ref, wq_ref, sw_ref, o_ref, acc_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        xq_ref[...], wq_ref[...], _CONTRACT_LAST,
        preferred_element_type=I32)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(F32) * sx_ref[...] * sw_ref[...].T


def qmm(xq, sx, wq, sw, *, block_m: int = 128, block_n: int = 128,
        block_k: int = 512, interpret: bool = True):
    """(M, K) int8 @ (N, K) int8 -> (M, N) f32.

    ``xq``/``sx``: rowwise-quantized activations + (M, 1) scales;
    ``wq``/``sw``: per-output-channel quantized weights + (N, 1) scales
    (the ``quantize_rows(w.T)`` layout). int8 zero-padding is exact, so
    arbitrary shapes cost nothing but the pad copy."""
    m, kdim = xq.shape
    n = wq.shape[0]
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, kdim)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-kdim) % bk
    if pm or pk:
        xq = jnp.pad(xq, ((0, pm), (0, pk)))
        sx = jnp.pad(sx, ((0, pm), (0, 0)))
    if pn or pk:
        wq = jnp.pad(wq, ((0, pn), (0, pk)))
        sw = jnp.pad(sw, ((0, pn), (0, 0)))
    mp, npad, kp = m + pm, n + pn, kdim + pk
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_qmm_kernel, nk=nk),
        grid=(mp // bm, npad // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, 1), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, npad), F32),
        scratch_shapes=[pltpu.VMEM((bm, bn), I32)],
        interpret=interpret,
    )(xq, jnp.asarray(sx, F32), wq, jnp.asarray(sw, F32))
    return out[:m, :n]
