"""jnp oracle for the int8 matmul kernel.

One ``lax.dot_general`` with int32 accumulation — integer sums are exact,
so the tiled kernel must reproduce this bit-for-bit. Also the op GSPMD
shards under a serving mesh (the kernel is single-device)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

F32 = jnp.float32
I32 = jnp.int32

_CONTRACT_LAST = (((1,), (1,)), ((), ()))


def qmm_ref(xq, sx, wq, sw):
    """(M, K) int8 x (N, K) int8 -> (M, N) f32, rowwise scales applied."""
    acc = lax.dot_general(xq, wq, _CONTRACT_LAST,
                          preferred_element_type=I32)
    return acc.astype(F32) * jnp.asarray(sx, F32) * jnp.asarray(sw, F32).T
