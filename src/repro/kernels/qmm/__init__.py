from repro.kernels.qmm.kernel import qmm  # noqa: F401
from repro.kernels.qmm.ops import int8_matmul, quantize_weight  # noqa: F401
from repro.kernels.qmm.ref import qmm_ref  # noqa: F401
