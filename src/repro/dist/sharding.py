"""GSPMD-style logical-axis sharding rules (see dist/README.md).

Tensors everywhere in the codebase name their dimensions with *logical*
axis names; a :class:`Ruleset` maps those names onto *mesh* axes. The
model code never mentions a mesh: it calls :func:`constrain` with logical
names, and the active ruleset (installed by :func:`use_rules`) decides
what — if anything — that means physically.

Contract (load-bearing for the CPU test suite):

* **No active ruleset** — ``constrain`` is the identity, ``axis_size``
  returns 1, ``kv_repeat`` returns 1. Pure-CPU tests and examples run
  the exact same model code with zero sharding machinery.
* **Active ruleset** — ``constrain`` lowers to
  ``jax.lax.with_sharding_constraint`` with a ``NamedSharding`` derived
  from the rules. A logical axis silently falls back to replicated when
  (a) its mapped mesh axes are absent from the mesh (e.g. "pod" on a
  2-axis host mesh), (b) the dimension size is not divisible by the
  mapped mesh size, or (c) an earlier dimension of the same tensor
  already claimed the mesh axis (first dimension wins).

Rules are resolved per call, so per-deployment overrides (e.g. serving's
``{"fsdp": None}`` weight replication, or ``{"cache_seq": "model"}`` KV
cache sequence sharding) are one dict away — see
``launch/steps.serve_overrides``.

The active ruleset lives in a ``contextvars.ContextVar`` so it is safe
under threads and under jax tracing (tracing happens in the thread that
entered ``use_rules``; the ruleset is captured at trace time, which is
exactly the AOT-lowering semantics the dry-run relies on).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
from typing import Mapping, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# A rule value: replicated (None), one mesh axis, or a tuple of mesh axes
# (sharded over their product, major first).
MeshAxes = Union[None, str, Tuple[str, ...]]

# Logical axis -> mesh axes. This table is the whole sharding policy:
#   activations: batch is data-parallel across pods; seq/ctx replicated by
#     default (override ctx -> "model" for Megatron-style sequence
#     parallelism); ctx_attn is the context-parallel fallback used when a
#     config's head count cannot shard over "model".
#   params: fsdp is the ZeRO-3 axis; heads/kv/ff/vocab are the tensor-
#     parallel contractions on "model"; experts maps to the "expert" mesh
#     axis carried by the EP mesh variants (make_production_mesh(ep=True),
#     make_host_mesh(expert=)) — on non-EP meshes it falls back replicated
#     and MoE weights stay 2D-sharded (fsdp x ff).
#   cap: MoE capacity slots; sharding them over "model" turns the expert
#     down-projection's cross-"model" reduction into a reduce-scatter.
#   data/model/pod: passthrough names so launch code can talk about mesh
#     axes through the same interface.
DEFAULT_RULES: dict = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "ctx": None,
    "ctx_attn": "model",
    "cache_seq": None,
    "embed": None,
    "cap": "model",
    # params
    "fsdp": "data",
    "heads": "model",
    "kv": "model",
    "ff": "model",
    "experts": "expert",
    "vocab": "model",
    "layers": None,
    # mesh passthrough
    "data": "data",
    "model": "model",
    "pod": "pod",
}


def _as_tuple(axes: MeshAxes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def _check_rule(name, axes) -> None:
    if axes is None or isinstance(axes, str):
        return
    if isinstance(axes, (tuple, list)) and all(
        isinstance(a, str) for a in axes
    ):
        return
    raise TypeError(
        f"rule {name!r} must map to None, a mesh axis name, or a tuple of "
        f"mesh axis names; got {axes!r}"
    )


@dataclasses.dataclass(frozen=True)
class Ruleset:
    """An (immutable) mesh + logical->mesh axis mapping."""

    mesh: jax.sharding.Mesh
    rules: Mapping[str, MeshAxes]

    def resolve(self, name: Optional[str]) -> Tuple[str, ...]:
        """Mesh axes a logical name maps to, restricted to axes the mesh
        actually has. Unknown names are an error (catches axis typos)."""
        if name is None:
            return ()
        try:
            axes = self.rules[name]
        except KeyError:
            raise KeyError(
                f"unknown logical axis {name!r}; known: "
                f"{sorted(self.rules)}"
            ) from None
        return tuple(a for a in _as_tuple(axes) if a in self.mesh.shape)

    def axis_size(self, name: Optional[str]) -> int:
        """Total number of shards a logical axis maps onto (1 = replicated)."""
        size = 1
        for a in self.resolve(name):
            size *= self.mesh.shape[a]
        return size

    def spec(self, axes, shape=None) -> P:
        """PartitionSpec for a tensor whose dims carry these logical names.

        ``shape`` (when given) enables the divisibility fallback: a dim
        that can't be evenly split over its mapped mesh axes stays
        replicated rather than erroring inside XLA.
        """
        if shape is not None and len(shape) != len(axes):
            raise ValueError(f"rank mismatch: axes={axes} shape={shape}")
        used: set = set()
        entries = []
        for i, name in enumerate(axes):
            picked = []
            size = 1
            for a in self.resolve(name):
                if a in used:
                    continue
                s = self.mesh.shape[a]
                if shape is not None and int(shape[i]) % (size * s):
                    continue
                picked.append(a)
                size *= s
            used.update(picked)
            if not picked:
                entries.append(None)
            elif len(picked) == 1:
                entries.append(picked[0])
            else:
                entries.append(tuple(picked))
        return P(*entries)

    def sharding(self, axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))

    def with_overrides(self, overrides: Optional[Mapping[str, MeshAxes]]):
        if not overrides:
            return self
        for k, v in overrides.items():
            _check_rule(k, v)
        return Ruleset(self.mesh, {**self.rules, **overrides})


_ACTIVE: contextvars.ContextVar[Optional[Ruleset]] = contextvars.ContextVar(
    "repro_dist_ruleset", default=None
)


def active() -> Optional[Ruleset]:
    """The ruleset installed by the innermost ``use_rules``, or None."""
    return _ACTIVE.get()


@contextlib.contextmanager
def use_rules(mesh, overrides: Optional[Mapping[str, MeshAxes]] = None, *,
              base: Optional[Mapping[str, MeshAxes]] = None):
    """Install a Ruleset(mesh, DEFAULT_RULES + overrides) for the block.

    Nestable and re-entrant; yields the ruleset so callers can also pass
    it explicitly (``shardings_from_template(tmpl, rs)``).
    """
    rs = Ruleset(mesh, dict(DEFAULT_RULES if base is None else base))
    rs = rs.with_overrides(overrides)
    token = _ACTIVE.set(rs)
    try:
        yield rs
    finally:
        _ACTIVE.reset(token)


def axis_size(name: str) -> int:
    """Shard count of a logical axis under the active ruleset (1 outside)."""
    rs = active()
    return 1 if rs is None else rs.axis_size(name)


def constrain(x, axes):
    """Pin a tensor's sharding by logical axis names.

    Identity when no ruleset is active or the mesh is a single device, so
    model code is unconditionally callable from plain CPU tests.
    """
    rs = active()
    if rs is None or rs.mesh.size <= 1:
        return x
    spec = rs.spec(axes, x.shape)
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(rs.mesh, spec))


def put(x, axes):
    """``jax.device_put`` with the sharding the active rules give these
    logical axis names (with the same divisibility/absent-axis fallbacks
    as :func:`constrain`).

    Identity when no ruleset is active or the mesh is a single device —
    the serving path's input placement degrades to plain host arrays on
    CPU tests. Unlike ``constrain`` this runs *outside* jit: it commits
    the array to the mesh so a jitted program with unspecified
    in_shardings picks the distributed layout up from its arguments.
    """
    rs = active()
    if rs is None or rs.mesh.size <= 1:
        return x
    return jax.device_put(x, rs.sharding(axes, x.shape))


def kv_repeat(kv_heads: int, n_heads: int) -> int:
    """KV-head repeat factor that makes GQA caches shardable over "model".

    With q heads sharded m ways, each shard needs its own whole kv heads;
    when kv_heads doesn't divide by m, repeating kv heads up to
    lcm(kv_heads, m) re-aligns the (KV-major) q groups with the shards.
    Returns 1 when nothing shards (no mesh, heads unshardable, or kv
    already divisible) — i.e. plain GQA on CPU.
    """
    m = axis_size("heads")
    if m <= 1 or n_heads % m or kv_heads % m == 0:
        return 1
    lcm = kv_heads * m // math.gcd(kv_heads, m)
    # lcm divides n_heads here: kv_heads | n_heads (GQA invariant) and
    # m | n_heads (checked above) — so the repeated grouping stays exact.
    if lcm > n_heads:
        return 1
    return lcm // kv_heads
