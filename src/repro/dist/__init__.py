"""Distributed-execution layer: logical-axis sharding rules.

``repro.dist.sharding`` is the single place where logical tensor axis
names ("batch", "heads", "ff", ...) meet physical mesh axes ("pod",
"data", "model"). Model and launch code only ever speak logical names.
"""
from repro.dist import sharding  # noqa: F401
from repro.dist.sharding import (  # noqa: F401
    DEFAULT_RULES,
    Ruleset,
    active,
    axis_size,
    constrain,
    kv_repeat,
    put,
    use_rules,
)
