from repro.runtime.stragglers import StragglerWatchdog  # noqa: F401
from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: F401
