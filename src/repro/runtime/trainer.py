"""Fault-tolerant training driver.

Checkpoint/restart: periodic async checkpoints; --resume restores the
latest and, because the data pipeline is a pure function of step, the loss
trajectory continues exactly. Failure injection (fail_at_step) exercises
the restart path in tests. Straggler watchdog hooks per-step wall time.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import make_batch_fn
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import AdamW, cosine_schedule
from repro.runtime.stragglers import Action, StragglerWatchdog


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerConfig:
    seq: int = 128
    global_batch: int = 8
    steps: int = 50
    ckpt_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    lr: float = 3e-4
    warmup: int = 10
    seed: int = 0
    remat: str = "none"
    grad_accum: int = 1
    fail_at_step: Optional[int] = None  # failure injection (tests)
    keep: int = 3


class Trainer:
    def __init__(self, cfg, tc: TrainerConfig, *,
                 on_straggler: Optional[Callable] = None):
        self.cfg = cfg
        self.tc = tc
        self.opt = AdamW(lr=cosine_schedule(tc.lr, tc.warmup, tc.steps))
        self.step_fn = jax.jit(
            make_train_step(cfg, self.opt, remat=tc.remat,
                            grad_accum=tc.grad_accum),
            donate_argnums=(0,))
        self.batch_at = make_batch_fn(cfg, tc.seq, tc.global_batch, tc.seed)
        self.ckpt = CheckpointManager(tc.ckpt_dir, keep=tc.keep)
        self.watchdog = StragglerWatchdog()
        self.on_straggler = on_straggler
        self.history: list[tuple[int, float]] = []

    def init_state(self):
        params = init_params(self.cfg, jax.random.PRNGKey(self.tc.seed))
        return {"params": params, "opt": self.opt.init(params)}

    def run(self, resume: bool = False):
        state = self.init_state()
        start = 0
        if resume and self.ckpt.latest() is not None:
            state, start = self.ckpt.restore(state)
            start += 1
        for step in range(start, self.tc.steps):
            if self.tc.fail_at_step is not None and step == self.tc.fail_at_step:
                self.ckpt.wait()
                raise InjectedFailure(f"injected failure at step {step}")
            t0 = time.time()
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.batch_at(step).items()}
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            act = self.watchdog.update(dt)
            if act is not Action.NONE and self.on_straggler:
                self.on_straggler(step, act, dt)
            self.history.append((step, loss))
            if step % self.tc.ckpt_every == 0 or step == self.tc.steps - 1:
                self.ckpt.save(step, state)
        self.ckpt.wait()
        return state, np.array(self.history)
