"""Straggler mitigation: per-step wall-time watchdog.

At 1000+ nodes, one slow host gates every synchronous collective. The
watchdog keeps an EWMA/variance of step time; a step slower than
`threshold`x the EWMA raises WARN, and `patience` consecutive WARNs raise
EXCLUDE — the control plane's signal to checkpoint, drop the slow data-
parallel group, and continue on a shrunken mesh (elastic restore path,
tested in test_runtime.py)."""
from __future__ import annotations

import dataclasses
import enum


class Action(enum.Enum):
    NONE = 0
    WARN = 1
    EXCLUDE = 2


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 2.0  # x EWMA to flag
    patience: int = 3  # consecutive flags before EXCLUDE
    alpha: float = 0.2  # EWMA weight
    warmup: int = 5  # steps before judging

    ewma: float = 0.0
    seen: int = 0
    strikes: int = 0
    excluded: bool = False

    def update(self, step_time_s: float) -> Action:
        self.seen += 1
        if self.seen <= self.warmup:
            self.ewma = (step_time_s if self.seen == 1 else
                         self.alpha * step_time_s +
                         (1 - self.alpha) * self.ewma)
            return Action.NONE
        slow = step_time_s > self.threshold * self.ewma
        # slow steps do not poison the baseline
        if not slow:
            self.ewma = (self.alpha * step_time_s +
                         (1 - self.alpha) * self.ewma)
            self.strikes = 0
            return Action.NONE
        self.strikes += 1
        if self.strikes >= self.patience:
            self.excluded = True
            self.strikes = 0
            return Action.EXCLUDE
        return Action.WARN
