"""AdamW with global-norm clipping and cosine schedule (pure JAX, no optax).

Optimizer state (m, v) mirrors the parameter pytree, so the same sharding
tree applies — with FSDP-style 2D weight sharding this is ZeRO-3: params,
grads and moments are all fully sharded across ('data','model').
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(F32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac) * 0.5 *
                      (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros_like(p, dtype=F32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g.astype(F32) * scale, grads)
        else:
            grads = jax.tree.map(lambda g: g.astype(F32), grads)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g,
                         state["v"], grads)
        tf = step.astype(F32)
        bc1 = 1 - b1**tf
        bc2 = 1 - b2**tf
        lr = self.lr(step) if callable(self.lr) else self.lr

        def upd(p, mm, vv):
            mhat = mm / bc1
            vhat = vv / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * p
            return (p - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}, {
            "grad_norm": gnorm, "lr": jnp.asarray(lr, F32)}
