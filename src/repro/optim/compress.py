"""Int8 gradient compression with error feedback (distributed-optimization
trick for the DP all-reduce at 1000-node scale).

compress: g_eff = g + error_prev; q, s = int8(g_eff); error = g_eff - dq(q).
The all-reduce then moves 1/4 the bytes (int8 + per-row fp32 scales); error
feedback makes the quantisation noise telescope instead of accumulate —
convergence matches fp32 within noise on the e2e example.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant.ref import dequantize_ref, quantize_ref

F32 = jnp.float32


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)


def _rows(x):
    return x.reshape(-1, x.shape[-1]) if x.ndim >= 2 else x.reshape(1, -1)


def compress_tree(grads, error):
    """Returns (quantised tree of (q, scale), new_error)."""

    def one(g, e):
        g_eff = g.astype(F32) + e
        q, s = quantize_ref(_rows(g_eff))
        dq = dequantize_ref(q, s, F32).reshape(g.shape)
        return (q, s), g_eff - dq

    flat = jax.tree.map(one, grads, error)
    qtree = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_error = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
    return qtree, new_error


def decompress_tree(qtree, like):
    def one(qs, g):
        q, s = qs
        return dequantize_ref(q, s, F32).reshape(g.shape)

    return jax.tree.map(one, qtree, like,
                        is_leaf=lambda x: isinstance(x, tuple))


def compressed_grads(grads, error):
    """Round-trip (the collective itself is inserted by SPMD on the summed
    result); returns (grads_hat, new_error)."""
    qtree, new_error = compress_tree(grads, error)
    return decompress_tree(qtree, grads), new_error
