"""repro.sim — fleet-scale adaptive-splitting simulation engine."""
from repro.sim.engine import (FleetResult, TP_CLIP_MBPS, estimate_fleet,
                              run_controllers, simulate_fleet,
                              simulate_fleet_looped, split_metrics)

__all__ = ["FleetResult", "TP_CLIP_MBPS", "estimate_fleet",
           "run_controllers", "simulate_fleet", "simulate_fleet_looped",
           "split_metrics"]
