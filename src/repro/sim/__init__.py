"""repro.sim — fleet-scale adaptive-splitting simulation engine."""
from repro.sim.cells import (CellsResult, attach_ring, build_cells_episode,
                             cell_load, coupled_interference_mw,
                             handover_grid, jain_index, ring_coupling,
                             simulate_cells)
from repro.sim.engine import (FleetResult, TP_CLIP_MBPS, emit_period_samples,
                              estimate_fleet, run_controllers, run_scheduled,
                              simulate_fleet, simulate_fleet_looped,
                              split_metrics)
from repro.sim.online import (DriftConfig, DriftState, OnlineConfig,
                              OnlineStats, ReplayBuffer, ReplayBufferSSM,
                              buffer_add, buffer_add_masked, buffer_add_ssm,
                              buffer_count, buffer_data, buffer_init,
                              drift_init, drift_step, drift_threshold,
                              online_estimate_fleet, online_step_program)
from repro.sim.pool import (LifecycleStats, PoolPrograms, PoolState,
                            pool_init, pool_programs, simulate_pool)
from repro.sim.sched import (POLICIES, SchedulerConfig, SchedulerState,
                             cell_shares, scheduler_init, scheduler_step)
from repro.sim.serving import (ServingMesh, make_serving_mesh,
                               replicate_params, serving_program,
                               sharded_fleet_estimate,
                               sharded_ssm_estimate, ssm_serving_program)
from repro.sim.telemetry import (EVENT_NAMES, HostTelemetry, StageStat,
                                 TelemetryConfig, TelemetryEvent,
                                 TelemetryRecord, TelemetryState,
                                 telemetry_decode, telemetry_init,
                                 telemetry_step, timed, timed_stages,
                                 to_jsonl, to_prometheus, trace_capture)
from repro.sim.telemetry import stage as telemetry_stage

__all__ = ["CellsResult", "DriftConfig", "DriftState", "EVENT_NAMES",
           "FleetResult", "HostTelemetry",
           "LifecycleStats", "OnlineConfig", "OnlineStats", "POLICIES",
           "PoolPrograms", "PoolState", "ReplayBuffer", "ReplayBufferSSM",
           "SchedulerConfig",
           "SchedulerState", "ServingMesh", "StageStat",
           "TP_CLIP_MBPS", "TelemetryConfig", "TelemetryEvent",
           "TelemetryRecord", "TelemetryState", "attach_ring",
           "buffer_add", "buffer_add_masked", "buffer_add_ssm",
           "buffer_count", "buffer_data",
           "buffer_init", "build_cells_episode", "cell_load", "cell_shares",
           "coupled_interference_mw", "drift_init", "drift_step",
           "drift_threshold", "emit_period_samples", "estimate_fleet",
           "handover_grid", "jain_index", "make_serving_mesh",
           "online_estimate_fleet", "online_step_program", "pool_init",
           "pool_programs", "replicate_params", "ring_coupling",
           "run_controllers", "run_scheduled", "scheduler_init",
           "scheduler_step", "serving_program", "sharded_fleet_estimate",
           "sharded_ssm_estimate", "ssm_serving_program",
           "simulate_cells", "simulate_fleet", "simulate_fleet_looped",
           "simulate_pool", "split_metrics", "telemetry_decode",
           "telemetry_init", "telemetry_stage", "telemetry_step", "timed",
           "timed_stages", "to_jsonl", "to_prometheus", "trace_capture"]
