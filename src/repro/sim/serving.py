"""Mesh-sharded fleet estimator serving.

The one fleet layer PR 2/3 left unsharded was the per-report-period
estimator ``predict``: ``estimate_fleet`` ran the whole (N,) UE batch
through a single-device forward. This module runs that same forward as a
production-mesh SPMD program:

  * the UE batch (kpms window, IQ spectrogram, alloc ratio) is sharded
    over the mesh's ``data`` axis (and ``pod`` when present) through the
    ``batch`` rule of ``repro.dist.sharding`` — no new mechanism, the
    estimator's ``constrain`` annotations resolve against whatever mesh
    is active;
  * estimator weights stay replicated (their template axes are all
    ``None``), so per-period serving is pure data parallelism: zero
    cross-chip collectives in the forward, UE capacity scales linearly
    with chips until HBM/host bandwidth binds;
  * one per-report-period program is traced and compiled once per
    (estimator config, mesh, overrides, fleet shape) and reused for every
    report period of every episode batch — exactly the program an AF
    serving pod would run each 0.1 s tick.

Numerics: the sharded program computes the same per-UE forward as the
unsharded path (batch-only partitioning never re-associates a per-example
reduction), pinned allclose by ``tests/test_serving_mesh.py`` and the
``benchmarks/fleet.py --mesh`` sweep. The engine hook
(``estimate_fleet(..., serving=)``) therefore composes with
``simulate_fleet``/``run_scheduled`` without touching the sched=None
bit-identical guarantee, which only concerns the controller scan.

The slot-pool engine (``repro.sim.pool``) preserves this fixed-shape
contract under churn: its batch axis is the pool's ``capacity`` slots,
not the live population, so every per-period forward — frozen or online
— reuses the same compiled serving program at any occupancy; arrivals
and departures move the active mask, never the sharded shapes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as sh
from repro.estimator.model import EstimatorConfig, estimator_forward
from repro.estimator.serve import (check_quant, estimator_forward_int8,
                                   quantize_estimator)
from repro.estimator.ssm import (SSMConfig, reduce_forecasts,
                                 ssm_state_init, ssm_step)
from repro.launch.mesh import make_host_mesh


@dataclasses.dataclass(frozen=True)
class ServingMesh:
    """A mesh + rule overrides describing one estimator-serving deployment.

    ``overrides`` are ``dist.sharding`` rule replacements stored as sorted
    (name, mesh-axes) pairs so the config stays hashable (it keys the
    compiled-program cache). The defaults already shard ``batch`` over
    ``("pod", "data")`` and replicate estimator weights, so most
    deployments pass no overrides at all.
    """

    mesh: jax.sharding.Mesh
    overrides: Tuple[Tuple[str, sh.MeshAxes], ...] = ()

    @property
    def n_chips(self) -> int:
        return self.mesh.size

    def rule_overrides(self) -> dict:
        return dict(self.overrides)

    def describe(self) -> str:
        """``data=4,model=2`` style axis summary for benchmark records."""
        return ",".join(f"{a}={s}" for a, s in self.mesh.shape.items())


def make_serving_mesh(spec: str = "1x1",
                      overrides: Optional[Mapping[str, sh.MeshAxes]] = None
                      ) -> ServingMesh:
    """Build a host-device ServingMesh from a ``DxM`` / ``DxExM`` string.

    Two factors are (data, model); three are (data, expert, model) — the
    EP variant that finally gives the reserved ``expert`` logical axis a
    physical home. Sizes are clamped to the host's device count with the
    same divisor-walking as ``make_host_mesh``, so any spec is
    constructible on any host (degrading to fewer shards, never erroring).
    """
    parts = [int(p) for p in spec.lower().split("x")]
    if len(parts) == 1:
        parts = [parts[0], 1]
    if len(parts) == 2:
        mesh = make_host_mesh(data=parts[0], model=parts[1])
    elif len(parts) == 3:
        mesh = make_host_mesh(data=parts[0], expert=parts[1], model=parts[2])
    else:
        raise ValueError(f"mesh spec {spec!r}: want DxM or DxExM")
    ov = tuple(sorted((overrides or {}).items()))
    return ServingMesh(mesh, ov)


@functools.lru_cache(maxsize=None)
def serving_program(ecfg: EstimatorConfig, serving: ServingMesh,
                    quant: Optional[str] = None):
    """The jitted per-report-period program for one deployment.

    Returns ``fn(params, kpms, iq, alloc) -> (N,) Mbps``. The serving
    ruleset is (re-)entered inside the traced function, so the estimator's
    ``constrain`` annotations bind to this deployment's mesh no matter
    when jit actually traces. Compiled once per input shape by jit's own
    cache; reused for every period.

    ``quant="int8"`` serves the int8 forward on a ``quantize_estimator``
    tree. GSPMD cannot partition a ``pallas_call``, so the mesh program
    takes the jnp oracle form (``use_kernel=False``) — bit-identical to
    the kernels, integer accumulation being exact (see
    ``estimator.serve``)."""
    check_quant(quant)
    mesh, overrides = serving.mesh, serving.rule_overrides()

    @jax.jit
    def fn(params, kpms, iq, alloc):
        with sh.use_rules(mesh, overrides), \
                jax.named_scope("estimator_fwd"):
            if quant == "int8":
                return estimator_forward_int8(ecfg, params, kpms, iq, alloc,
                                              use_kernel=False)
            return estimator_forward(ecfg, params, kpms, iq, alloc)

    return fn


def replicate_params(serving: ServingMesh, params):
    """Estimator params device-put replicated onto the deployment's mesh.

    This is also the whole weight-*refresh* path: ``serving_program``
    caches the compiled per-period program on (config, deployment) and
    takes the params as a runtime argument, so swapping adapted weights in
    — the ``repro.sim.online`` trainer does this after every adaptation
    burst — is one replicated ``device_put`` and a cache hit: no retrace,
    no recompile (the refreshed tree has the same shapes, dtypes and
    replicated sharding the program was compiled for).
    """
    return jax.device_put(params, NamedSharding(serving.mesh, P()))


def sharded_fleet_estimate(ecfg: EstimatorConfig, params, wins: np.ndarray,
                           iq: np.ndarray, alloc: np.ndarray,
                           serving: ServingMesh, tp_clip, *,
                           quant: Optional[str] = None,
                           window: Optional[int] = None) -> np.ndarray:
    """(N, T) Mbps: the mesh-sharded body of ``engine.estimate_fleet``.

    ``wins``: (N, T, WINDOW, 15) normalized KPM windows; ``iq``:
    (N, T, 2, n_sc, 14) spectrograms; ``alloc``: (N,) PRB ratios. Weights
    are replicated onto the mesh once; each period's slice is committed
    with the ``batch`` sharding (``dist.sharding.put``) and run through
    the cached per-period program.

    ``window``: the fused-featurize form — ``wins`` is then the
    (N, T + WINDOW, 15) *normalized trace* and period ``t``'s batch is the
    ``wins[:, t:t+window]`` view, so the (N, T, WINDOW, 15) window tensor
    is never materialized (same f32 elements, ~WINDOW x less memory).
    ``quant="int8"`` quantizes the weights once and serves the int8
    program (see ``serving_program``)."""
    check_quant(quant)
    n = wins.shape[0]
    t_steps = iq.shape[1]
    fn = serving_program(ecfg, serving, quant)
    if quant == "int8":
        # oracle quantizer: bit-identical to the kernel, and shardable
        params = quantize_estimator(params, use_kernel=False)
    params_r = replicate_params(serving, params)
    with sh.use_rules(serving.mesh, serving.rule_overrides()):
        alloc_d = sh.put(jnp.asarray(alloc, jnp.float32), ("batch",))
        est = np.empty((n, t_steps))
        for t in range(t_steps):
            win_t = wins[:, t] if window is None else wins[:, t:t + window]
            kpms_t = sh.put(jnp.asarray(win_t), ("batch", None, None))
            iq_t = sh.put(jnp.asarray(iq[:, t], jnp.float32),
                          ("batch", None, None, None))
            est[:, t] = np.clip(np.asarray(fn(params_r, kpms_t, iq_t,
                                              alloc_d)),
                                tp_clip[0], tp_clip[1])
    return est


STATE_AXES = ("batch", None, None, None, None)  # per-UE recurrent state


@functools.lru_cache(maxsize=None)
def ssm_serving_program(c: SSMConfig, serving: ServingMesh):
    """The recurrent per-report-period program for one deployment.

    Returns ``fn(params, state, feats) -> (state, (N, K+1) forecasts)``
    — one O(1) SSD ingest step for the whole fleet, state and report
    batch sharded over the mesh's ``batch`` rule, weights replicated.
    ``ssm_step`` is pure jnp (no ``pallas_call``), which is what makes
    this program GSPMD-partitionable at all; the chunked SSD kernel only
    serves offline sequence passes. Weight refresh after an adaptation
    burst is the same ``replicate_params`` cache-hit path the windowed
    program uses."""
    mesh, overrides = serving.mesh, serving.rule_overrides()

    @jax.jit
    def fn(params, state, feats):
        with sh.use_rules(mesh, overrides), \
                jax.named_scope("estimator_fwd"):
            return ssm_step(c, params, state, feats)

    return fn


def sharded_ssm_estimate(c: SSMConfig, params, feats: np.ndarray,
                         serving: ServingMesh, tp_clip, *,
                         n_periods: int) -> np.ndarray:
    """(N, T) Mbps: the mesh-sharded recurrent body of
    ``engine.estimate_fleet``.

    ``feats``: the (N, S, F) report-stream features
    (``estimator.ssm.episode_features``; an EpisodeBatch trace has
    S = n_periods + WINDOW). Every report column — warmup included —
    runs through the *same* cached step program an AF pod would run each
    0.1 s tick; period ``t``'s estimate is emitted at column ``off + t``
    with ``off = S - n_periods - 1`` (= WINDOW - 1: the windowed path's
    alignment, the final report left unconsumed just as it is there).
    Pinned allclose to the unsharded sequence pass by
    ``tests/test_estimator_ssm.py``."""
    n, s = feats.shape[:2]
    off = s - n_periods - 1  # column of period 0's report
    fn = ssm_serving_program(c, serving)
    params_r = replicate_params(serving, params)
    est = np.empty((n, n_periods))
    with sh.use_rules(serving.mesh, serving.rule_overrides()):
        state = sh.put(ssm_state_init(c, (n,)), STATE_AXES)
        for col in range(off + n_periods):
            feats_t = sh.put(jnp.asarray(feats[:, col], jnp.float32),
                             ("batch", None))
            state, fc = fn(params_r, state, feats_t)
            if col >= off:
                est[:, col - off] = np.clip(
                    reduce_forecasts(c, np.asarray(fc)),
                    tp_clip[0], tp_clip[1])
    return est
