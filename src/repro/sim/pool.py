"""Slot-pool fleet engine: continuous UE arrival/departure at fixed shape.

The batch-synchronous engine (``repro.sim.engine``) marches one fixed
N-UE cohort through T report periods in lockstep. Real traffic churns:
UEs attach, live for a while, and detach continuously — and a jitted
program whose shapes track the live population would retrace on every
arrival. This module keeps the *shapes* fixed and lets the *population*
move: a device-resident pool of ``capacity`` UE slots, an active mask,
and a free-list index stack (the replay-ring scatter idiom from
``repro.sim.online``) are threaded through one unified per-period step:

  admit    — pop free slots for the FIFO's ready arrivals through
             ``max_admits`` fixed lanes (excess arrivals queue and show
             up as admission latency); scatter-reset the slot's
             controller + scheduler state (``mode="drop"`` discards the
             unused lanes, so the write is one fixed-shape scatter);
  serve    — gather each active slot's session trace at its age, run the
             gNB scheduler masked to active slots
             (``scheduler_step(active=...)``: empty slots get no PRBs and
             shape no cell normalizer) and the split controllers as one
             ``vmap`` over slots;
  retire   — push slots whose sessions reached their dwell back onto the
             free stack (cumsum-packed scatter) and clear their mask.

The whole horizon runs as one ``lax.scan`` over periods (or a host loop
with the same jitted sub-steps when online adaptation must interleave),
so the compiled program is a function of (capacity, horizon, session
count, lanes) only — occupancy can swing 10–90% without a retrace.

Sessions come from ``repro.channel.scenarios.make_churn_schedule`` (the
arrival/dwell realisation) plus an ``EpisodeBatch`` with one row per
session (its channel life). ``simulate_fleet(churn=...)`` is the public
entry; ``churn=None`` never enters this module (the engine's
batch-synchronous path is the PR 5 program unchanged, pinned by
``tests/test_sim_pool.py``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.channel import throughput as tpmod
from repro.channel.scenarios import WINDOW, ChurnSchedule, EpisodeBatch
from repro.core.controller import (PENDING_NONE, ControllerConfig,
                                   ControllerState, controller_init,
                                   controller_step)
from repro.core.energy import EDGE_A40X2, UE_VM_2CORE, DeviceProfile
from repro.core.profiles import SplitProfile
from repro.core.pso import NO_SPLIT, TP_CLIP_MBPS, StackedLookupTable
from repro.sim import telemetry as telmod
from repro.sim.sched import (SchedulerConfig, SchedulerState, scheduler_init,
                             scheduler_step)
from repro.sim.serving import ServingMesh
from repro.sim.telemetry import TelemetryConfig

F32 = jnp.float32
I32 = jnp.int32


class PoolState(NamedTuple):
    """The device-resident slot pool carried across report periods.

    ``free[:n_free]`` is a stack of currently-empty slot indices; every
    slot is either active or on the stack, never both (the conservation
    invariant ``tests/test_sim_pool.py`` pins). ``next_arrival`` is the
    pool's cursor into the global admission FIFO."""

    active: jax.Array  # (S,) bool — slot holds a live session
    sid: jax.Array  # (S,) i32 — session id occupying the slot
    age: jax.Array  # (S,) i32 — periods served so far (0 on admission)
    free: jax.Array  # (S,) i32 — stack of free slot indices
    n_free: jax.Array  # i32 scalar — stack depth
    next_arrival: jax.Array  # i32 scalar — FIFO cursor
    ctl: ControllerState  # (S,)-batched controller states
    sched: SchedulerState  # (S,)-batched scheduler state


def pool_init(capacity: int, warm_split=NO_SPLIT,
              avg0: float = 1.0) -> PoolState:
    """An empty pool: every slot on the free stack, ordered so slot 0 is
    admitted first (readable traces; any order is correct)."""
    if capacity <= 0:
        raise ValueError(f"capacity must be positive: {capacity}")
    s = int(capacity)
    return PoolState(
        active=jnp.zeros((s,), bool),
        sid=jnp.zeros((s,), I32),
        age=jnp.zeros((s,), I32),
        free=jnp.arange(s - 1, -1, -1, dtype=I32),
        n_free=jnp.asarray(s, I32),
        next_arrival=jnp.zeros((), I32),
        ctl=controller_init(warm_split, batch_shape=(s,)),
        sched=scheduler_init(s, avg0))


class PoolPrograms(NamedTuple):
    """Jitted per-period programs for one (controller, scheduler, lanes)
    config. ``sweep`` runs the whole horizon as one scan; ``admit`` and
    ``serve_retire`` are the same step split in two so a host loop (the
    online path, or an invariant test) can interleave work between
    admission and service; ``gather`` pulls the active slots' estimator
    inputs for a live forward."""

    sweep: object
    admit: object
    serve_retire: object
    gather: object


@functools.lru_cache(maxsize=None)
def pool_programs(ewma_alpha: float, hysteresis_steps: int,
                  fallback_split: int,
                  sched: Optional[SchedulerConfig] = None, n_cells: int = 1,
                  max_admits: int = 1,
                  telem: Optional[TelemetryConfig] = None) -> PoolPrograms:
    """Compile the pool step once per configuration (jit's own cache then
    handles distinct (capacity, sessions, horizon) shapes — churn moves
    the population, never the shapes, so the program never retraces).

    ``telem`` (default None) swaps ``sweep`` for the telemetry variant:
    the same scan additionally carries a ``TelemetryState``, folding each
    period's masked metrics into it and logging per-lane EV_ADMIT /
    aggregate EV_DEPART events into the device ring. ``telem=None``
    returns the exact prior programs — the variant is a separate cache
    entry, never a branch inside the default trace."""
    cfg = ControllerConfig(ewma_alpha, hysteresis_steps, fallback_split)
    step = functools.partial(controller_step, cfg=cfg)
    a_lanes = int(max_admits)

    def _admit(st: PoolState, t, ready_end_t, arrival_t, warm):
        s = st.active.shape[0]
        m = arrival_t.shape[0]
        lane = jnp.arange(a_lanes, dtype=I32)
        k = jnp.minimum(jnp.minimum(ready_end_t - st.next_arrival,
                                    st.n_free), a_lanes)
        valid = lane < k
        sid_new = st.next_arrival + lane
        slot = st.free[jnp.clip(st.n_free - 1 - lane, 0, s - 1)]
        tgt = jnp.where(valid, slot, s)  # s -> dropped by the scatters
        warm_i = jnp.asarray(warm, I32)
        ctl = ControllerState(
            tp_ewma=st.ctl.tp_ewma.at[tgt].set(0.0, mode="drop"),
            has_ewma=st.ctl.has_ewma.at[tgt].set(False, mode="drop"),
            current_split=st.ctl.current_split.at[tgt].set(
                warm_i, mode="drop"),
            pending_split=st.ctl.pending_split.at[tgt].set(
                PENDING_NONE, mode="drop"),
            pending_count=st.ctl.pending_count.at[tgt].set(0, mode="drop"),
            step=st.ctl.step.at[tgt].set(0, mode="drop"))
        ssched = st.sched._replace(
            avg_tp=st.sched.avg_tp.at[tgt].set(1.0, mode="drop"))
        lat = jnp.where(valid,
                        t - arrival_t[jnp.clip(sid_new, 0, m - 1)],
                        -1).astype(I32)
        new = st._replace(
            active=st.active.at[tgt].set(True, mode="drop"),
            sid=st.sid.at[tgt].set(sid_new, mode="drop"),
            age=st.age.at[tgt].set(0, mode="drop"),
            n_free=st.n_free - k,
            next_arrival=st.next_arrival + k,
            ctl=ctl, sched=ssched)
        return new, lat

    def _serve(st: PoolState, tables, est_t, true_t, cell_t):
        s = st.active.shape[0]
        act = st.active
        if tables.shape[0] == 1:  # shared lookup row (static at trace time)
            tab_t = jnp.broadcast_to(tables[0], (s, tables.shape[1]))
        else:
            tab_t = tables[jnp.clip(st.sid, 0, tables.shape[0] - 1)]
        if sched is None:
            share = act.astype(F32)  # informational; engine discards it
            eff_est = est_t
            new_ss = st.sched
        else:
            new_ss, share = scheduler_step(sched, n_cells, st.sched,
                                           cell_t, true_t, active=act)
            eff_est = est_t * share
        ctl, split = jax.vmap(step)(tab_t, st.ctl, eff_est)
        split = jnp.where(act, split, NO_SPLIT)
        return st._replace(ctl=ctl, sched=new_ss), split, share

    def _retire(st: PoolState, dwell):
        s = st.active.shape[0]
        m = dwell.shape[0]
        sidc = jnp.clip(st.sid, 0, m - 1)
        dep = st.active & (st.age + 1 >= dwell[sidc])
        n_dep = dep.sum(dtype=I32)
        pos = jnp.cumsum(dep.astype(I32)) - 1  # pack departures onto stack
        tgt = jnp.where(dep, st.n_free + pos, s)
        active = st.active & ~dep
        return st._replace(
            active=active,
            age=jnp.where(active, st.age + 1, st.age),
            free=st.free.at[tgt].set(jnp.arange(s, dtype=I32), mode="drop"),
            n_free=st.n_free + n_dep), n_dep

    def _gather_tp(st: PoolState, arr):
        m, el = arr.shape
        val = arr[jnp.clip(st.sid, 0, m - 1), jnp.clip(st.age, 0, el - 1)]
        return jnp.where(st.active, val.astype(F32), 0.0)

    @jax.jit
    def admit(st, t, ready_end_t, arrival_t, warm):
        return _admit(st, t, ready_end_t, arrival_t, warm)

    @jax.jit
    def serve_retire(st, tables, est_t, true, cell, dwell):
        act, sid, age = st.active, st.sid, st.age
        true_t = _gather_tp(st, true)
        cell_t = cell[jnp.clip(sid, 0, cell.shape[0] - 1)]
        st, split, share = _serve(st, tables, est_t, true_t, cell_t)
        st, n_dep = _retire(st, dwell)
        return st, (act, sid, age, split, share, n_dep)

    @jax.jit
    def gather(st, wins, iq, alloc, true):
        m = true.shape[0]
        el = true.shape[1]
        sidc = jnp.clip(st.sid, 0, m - 1)
        agec = jnp.clip(st.age, 0, el - 1)
        if wins.ndim == 4:  # (M, T, WINDOW, 15) precomputed windows
            k = wins[sidc, agec]
        else:  # fused featurize: (M, T + WINDOW, 15) normalized trace —
            # slot age a reads the trace span [a, a + WINDOW) directly,
            # so the windowed tensor is never materialized
            k = wins[sidc[:, None], agec[:, None]
                     + jnp.arange(WINDOW, dtype=I32)[None]]
        return (k, iq[sidc, agec], alloc[sidc],
                _gather_tp(st, true), st.active)

    if telem is None:
        @jax.jit
        def sweep(st0, tables, warm, est, true, cell, dwell, arrival_t,
                  ready_end):
            t_steps = ready_end.shape[0]

            def body(st, xs):
                t, ready_t = xs
                st, lat = _admit(st, t, ready_t, arrival_t, warm)
                act, sid, age = st.active, st.sid, st.age
                est_t = _gather_tp(st, est)
                true_t = _gather_tp(st, true)
                cell_t = cell[jnp.clip(sid, 0, cell.shape[0] - 1)]
                st, split, share = _serve(st, tables, est_t, true_t, cell_t)
                st, n_dep = _retire(st, dwell)
                return st, (act, sid, age, split, share, lat, n_dep)

            return lax.scan(body, st0,
                            (jnp.arange(t_steps, dtype=I32), ready_end))

        return PoolPrograms(sweep=sweep, admit=admit,
                            serve_retire=serve_retire, gather=gather)

    @jax.jit
    def sweep_telem(st0, ts0, tables, warm, est, true, cell, dwell,
                    arrival_t, ready_end, dconst, dbytes):
        t_steps = ready_end.shape[0]

        def body(carry, xs):
            st, ts = carry
            t, ready_t = xs
            sid0 = st.next_arrival  # lanes admit sessions sid0, sid0+1, ...
            st, lat = _admit(st, t, ready_t, arrival_t, warm)
            act, sid, age = st.active, st.sid, st.age
            est_t = _gather_tp(st, est)
            true_t = _gather_tp(st, true)
            cell_t = cell[jnp.clip(sid, 0, cell.shape[0] - 1)]
            st, split, share = _serve(st, tables, est_t, true_t, cell_t)
            st, n_dep = _retire(st, dwell)
            with jax.named_scope("telemetry_step"):
                eff = None
                if sched is not None:
                    # what split_metrics sees: PRB-scaled, floored
                    eff = jnp.maximum(true_t * jnp.clip(share, 0.0, 1.0),
                                      tpmod.PRB_FLOOR_MBPS)
                ts, row = telmod.telemetry_step(
                    telem, ts, period=t, split=split, est_tp=est_t,
                    true_tp=true_t, eff_tp=eff, share=share, active=act,
                    dconst=dconst, dbytes=dbytes,
                    admit_sid=sid0 + jnp.arange(a_lanes, dtype=I32),
                    admit_lat=lat, n_depart=n_dep)
            return (st, ts), (act, sid, age, split, share, lat, n_dep, row)

        return lax.scan(body, (st0, ts0),
                        (jnp.arange(t_steps, dtype=I32), ready_end))

    return PoolPrograms(sweep=sweep_telem, admit=admit,
                        serve_retire=serve_retire, gather=gather)


@dataclasses.dataclass
class LifecycleStats:
    """Per-slot lifecycle accounting of one churned run
    (``FleetResult.lifecycle``)."""

    capacity: int  # pool slots S
    n_sessions: int  # sessions in the admission FIFO
    n_admitted: int  # sessions admitted within the horizon
    occupancy: np.ndarray  # (T,) active slots per period
    admitted: np.ndarray  # (T,) admissions per period
    departed: np.ndarray  # (T,) departures per period
    admit_latency: np.ndarray  # (n_admitted,) periods queued, in
    # admission order — 0 means admitted the period it arrived

    @property
    def ue_steps(self) -> int:
        """Total slot-periods actually served (the churn benchmark's
        throughput numerator)."""
        return int(self.occupancy.sum())

    def p99_admit_latency(self) -> float:
        """99th-percentile admission queue time in periods."""
        if self.admit_latency.size == 0:
            return 0.0
        return float(np.percentile(self.admit_latency, 99))


def _pool_validate(sessions: EpisodeBatch, schedule: ChurnSchedule,
                   capacity: int, cell, sched) -> None:
    if capacity <= 0:
        raise ValueError(f"capacity must be positive: {capacity}")
    m = schedule.n_sessions
    if m == 0:
        raise ValueError("churn schedule has no sessions; raise the "
                         "arrival rate or the horizon")
    if sessions.n_ues != m:
        raise ValueError(
            f"episode has {sessions.n_ues} session rows but the schedule "
            f"has {m}; generate one episode row per scheduled session")
    if int(schedule.dwell.min(initial=1)) < 1:
        raise ValueError("session dwell times must be >= 1 period")
    if schedule.max_dwell > sessions.n_steps:
        raise ValueError(
            f"longest dwell ({schedule.max_dwell} periods) exceeds the "
            f"session trace length ({sessions.n_steps}); generate episodes "
            "with T >= ChurnConfig.max_dwell")
    if sched is not None:
        if cell is None:
            raise ValueError("a scheduler needs an (M,) per-session cell")
        if np.shape(cell) != (m,):
            raise ValueError(f"cell must be (M,) = ({m},): {np.shape(cell)}")


def _pool_tables(table, n_sessions: int) -> np.ndarray:
    if isinstance(table, StackedLookupTable):
        tables = np.asarray(table.tables)
        if tables.shape[0] != n_sessions:
            raise ValueError(
                f"stacked table has {tables.shape[0]} rows for "
                f"{n_sessions} sessions")
        return tables
    return np.asarray(table.table)[None]  # shared row, broadcast on device


def simulate_pool(sessions: EpisodeBatch, schedule: ChurnSchedule, table,
                  profile: SplitProfile, cfg: ControllerConfig, *,
                  capacity: int, warm_split=None, estimator=None,
                  serving: Optional[ServingMesh] = None, online=None,
                  fixed_split: Optional[int] = None,
                  ue: DeviceProfile = UE_VM_2CORE,
                  server: DeviceProfile = EDGE_A40X2,
                  sched: Optional[SchedulerConfig] = None,
                  cell: Optional[np.ndarray] = None, n_cells: int = 1,
                  quant: Optional[str] = None, fused: bool = False,
                  telemetry: Optional[TelemetryConfig] = None):
    """Run a churning UE population through the slot pool.

    ``sessions``: an ``EpisodeBatch`` with one row per scheduled session —
    row ``i`` is session ``i``'s channel life, consumed from trace step 0
    at admission regardless of *when* the session is admitted (each
    session carries its own episode; the pool recycles slots, not
    traces). ``schedule``: the realised arrival/dwell process
    (``make_churn_schedule``). ``table`` may be shared or a
    ``StackedLookupTable`` with one row per *session*.

    The result is a ``FleetResult`` whose rows are the pool's ``capacity``
    slots over ``schedule.horizon`` periods: ``result.active`` marks
    occupancy (metrics are NaN and splits ``NO_SPLIT`` on empty cells),
    and ``result.lifecycle`` carries the admission/departure accounting.
    ``sched``/``estimator``/``online``/``fixed_split`` compose exactly as
    in ``simulate_fleet``; ``cell`` is a static (M,) per-session attach.
    ``quant``/``fused`` are the int8-serving / fused-featurize switches,
    forwarded to the frozen and online estimate paths (defaults are the
    exact prior program). ``telemetry``: a
    ``repro.sim.telemetry.TelemetryConfig`` carries the metric plane
    through the pool scan (per-lane admission events with queue latency,
    aggregate departures, masked histograms/stats) into
    ``FleetResult.telemetry``; ``telemetry=None`` (default) never builds
    it.
    """
    from repro.sim.engine import FleetResult, estimate_fleet, split_metrics

    _pool_validate(sessions, schedule, capacity, cell, sched)
    if online is not None and estimator is None:
        raise ValueError("online adaptation needs an estimator")
    m = schedule.n_sessions
    t_steps = schedule.horizon
    true_np = np.asarray(sessions.tp_mbps, float)  # (M, L)
    if warm_split is None:
        warm_split = cfg.fallback_split if fixed_split is None else fixed_split
    tables_np = _pool_tables(table, m)
    programs = pool_programs(cfg.ewma_alpha, cfg.hysteresis_steps,
                             cfg.fallback_split, sched, int(n_cells),
                             int(schedule.max_admits), telem=telemetry)
    st0 = pool_init(capacity, warm_split)
    tables_d = jnp.asarray(tables_np, I32)
    warm_d = jnp.asarray(warm_split, I32)
    true_d = jnp.asarray(true_np, F32)
    cell_d = jnp.asarray(cell if cell is not None else np.zeros(m), I32)
    dwell_d = jnp.asarray(schedule.dwell, I32)
    arrival_d = jnp.asarray(schedule.arrival_t, I32)
    tel = dconst = dbytes = None
    if telemetry is not None:
        tel = telmod.HostTelemetry(telemetry)
        dconst = jnp.asarray(np.asarray(profile.d_ue(ue))
                             + np.asarray(profile.d_ser(server)), F32)
        dbytes = jnp.asarray(profile.data_bytes, F32)

    online_stats = None
    telem_rec = None
    if online is not None:
        outs, est_tp, online_stats = _online_pool_run(
            sessions, schedule, estimator, online, programs, st0, tables_d,
            warm_d, true_d, cell_d, dwell_d, arrival_d, serving=serving,
            fused=fused, telemetry=tel, tel_dconst=dconst,
            tel_dbytes=dbytes, tel_sched=sched is not None)
        act_ts, sid_ts, age_ts, split_ts, share_ts, lat_ts, dep_ts = outs
        if tel is not None:
            telem_rec = tel.decode()
    else:
        est_np = (estimate_fleet(sessions, estimator, serving=serving,
                                 quant=quant, fused=fused)
                  if estimator is not None else true_np)
        est_d = jnp.asarray(est_np, F32)
        if telemetry is None:
            _, ys = programs.sweep(st0, tables_d, warm_d, est_d, true_d,
                                   cell_d, dwell_d, arrival_d,
                                   jnp.asarray(schedule.ready_end, I32))
        else:
            (_, tel.ts), ys = programs.sweep(
                st0, tel.ts, tables_d, warm_d, est_d, true_d, cell_d,
                dwell_d, arrival_d, jnp.asarray(schedule.ready_end, I32),
                dconst, dbytes)
            ys, rows = ys[:7], ys[7]
            telem_rec = tel.decode(rows)
        act_ts, sid_ts, age_ts, split_ts, share_ts, lat_ts, dep_ts = (
            np.asarray(y) for y in ys)
        est_tp = None  # gathered below from the per-session estimates

    act = act_ts.T  # (S, T)
    sid = np.clip(sid_ts.T, 0, m - 1)
    age = np.clip(age_ts.T, 0, sessions.n_steps - 1)
    splits = split_ts.T.astype(np.int32)
    true_tp = np.where(act, true_np[sid, age], 0.0)
    if est_tp is None:
        est_src = est_np if estimator is not None else true_np
        est_tp = np.where(act, np.asarray(est_src, float)[sid, age], 0.0)
    shares = None
    if sched is not None:
        shares = np.where(act, share_ts.T, 0.0)
        eff_tp = tpmod.prb_scaled_mbps(true_tp, shares)
        est_tp = est_tp * shares  # what the controllers consumed
    else:
        eff_tp = true_tp

    def _metrics(l):
        d, p, e = split_metrics(profile, np.where(act, l, 0), eff_tp,
                                ue, server)
        nan = np.nan
        return (np.where(act, d, nan), np.where(act, p, nan),
                np.where(act, e, nan))

    delay, priv, energy = _metrics(splits)
    fixed = None
    if fixed_split is not None:
        fsplits = np.where(act, fixed_split, NO_SPLIT).astype(np.int32)
        fd, fp, fe = _metrics(fsplits)
        fixed = FleetResult(fsplits, true_tp, est_tp, fd, fp, fe,
                            prb_share=shares, active=act)
    lat_valid = lat_ts >= 0
    stats = LifecycleStats(
        capacity=int(capacity), n_sessions=m,
        n_admitted=int(lat_valid.sum()),
        occupancy=act_ts.sum(axis=1).astype(np.int64),
        admitted=lat_valid.sum(axis=1).astype(np.int64),
        departed=dep_ts.astype(np.int64),
        admit_latency=lat_ts[lat_valid].astype(np.int64))
    return FleetResult(splits, true_tp, est_tp, delay, priv, energy, fixed,
                       prb_share=shares, online=online_stats, active=act,
                       lifecycle=stats, telemetry=telem_rec)


@jax.jit
def _ssm_slot_reset(state, warm_all, sid, fresh):
    # scatter-free state reset: freshly admitted slots (active with age 0,
    # i.e. admitted this period) take their session's precomputed warmed
    # state; everyone else keeps the state they carried
    w = warm_all[jnp.clip(sid, 0, warm_all.shape[0] - 1)]
    return jnp.where(fresh.reshape(fresh.shape + (1,) * (w.ndim - 1)),
                     w, state)


@jax.jit
def _ssm_pool_gather(active, sid, age, feats, true):
    # each active slot's current report: session trace column
    # WINDOW - 1 + age (the warmup prefix was consumed at admission by
    # the precomputed warm state), plus the period's measured label
    m, l = true.shape
    sidc = jnp.clip(sid, 0, m - 1)
    agec = jnp.clip(age, 0, l - 1)
    f = feats[sidc, agec + (WINDOW - 1)]
    tp = jnp.where(active, true[sidc, agec].astype(F32), 0.0)
    return f, tp


def _online_pool_run(sessions, schedule, estimator, ocfg, programs, st0,
                     tables_d, warm_d, true_d, cell_d, dwell_d, arrival_d,
                     *, serving=None, tp_clip=TP_CLIP_MBPS,
                     fused=False, telemetry=None, tel_dconst=None,
                     tel_dbytes=None, tel_sched=False):
    """The closed-loop arm of ``simulate_pool``: the same admit/serve/
    retire step driven from a host loop so each period's estimator
    forward runs with the *current* weights, only active slots' samples
    are ring-ingested (``buffer_add_masked``), and drift-triggered
    adaptation bursts run between periods exactly as in
    ``repro.sim.online.online_estimate_fleet``.

    ``telemetry``: an optional ``telemetry.HostTelemetry`` — per period
    one jitted metric update (masked to the live slots, with admission
    lanes and departures) plus drift/burst/weight-swap events; the return
    shapes are unchanged, the caller decodes the record."""
    import contextlib

    from repro.checkpoint import CheckpointManager
    from repro.dist import sharding as sh
    from repro.estimator.ssm import SSMConfig
    from repro.estimator.train import fwd
    from repro.optim import AdamW
    from repro.sim.online import (OnlineStats, buffer_add_masked,
                                  buffer_count, buffer_data, buffer_init,
                                  drift_init, drift_step, drift_threshold,
                                  online_step_program)
    from repro.sim.serving import replicate_params, serving_program

    ecfg, params = estimator
    if isinstance(ecfg, SSMConfig):
        return _online_pool_run_ssm(
            sessions, schedule, estimator, ocfg, programs, st0, tables_d,
            warm_d, true_d, cell_d, dwell_d, arrival_d, serving=serving,
            tp_clip=tp_clip, telemetry=telemetry, tel_dconst=tel_dconst,
            tel_dbytes=tel_dbytes, tel_sched=tel_sched)
    if sessions.iq is None:
        raise ValueError(
            "online adaptation needs IQ spectrograms: generate the episode "
            "with include_iq=True")
    s_slots = int(st0.active.shape[0])
    if int(ocfg.capacity) < s_slots:
        raise ValueError(
            f"OnlineConfig.capacity ({ocfg.capacity}) must cover the pool "
            f"capacity ({s_slots}) for masked ingestion")
    t_steps = schedule.horizon
    if fused:
        # normalized trace instead of the WINDOW x window tensor; the
        # pool gather windows it per slot age (bit-identical elements)
        from repro.channel import kpm as kpmmod
        if sessions.kpms is None:
            raise ValueError("fused featurize needs raw KPM reports: "
                             "generate sessions with include_kpms=True")
        wins_d = jnp.asarray(
            kpmmod.normalize_kpms(sessions.kpms).astype(np.float32))
    else:
        wins_d = jnp.asarray(
            sessions.kpm_windows(normalize=True).astype(np.float32))
    iq_d = jnp.asarray(np.asarray(sessions.iq, np.float32))
    alloc_d = jnp.asarray(sessions.alloc_ratio.astype(np.float32))
    ready = np.asarray(schedule.ready_end, np.int64)
    opt = AdamW(lr=ocfg.lr, weight_decay=ocfg.weight_decay,
                clip_norm=ocfg.clip_norm)
    opt_state = opt.init(params)
    step_fn = online_step_program(ecfg, opt, serving)
    if serving is not None:
        predict_fn = serving_program(ecfg, serving)
        params = replicate_params(serving, params)
        ctx = sh.use_rules(serving.mesh, serving.rule_overrides())
    else:
        predict_fn = functools.partial(fwd, ecfg)
        ctx = contextlib.nullcontext()
    mgr = (CheckpointManager(ocfg.ckpt_dir, keep=ocfg.ckpt_keep)
           if ocfg.ckpt_dir else None)
    buf = buffer_init(ocfg.capacity, ecfg, serving=serving,
                      quant=ocfg.ring_quant)
    dstate = drift_init()
    rng = np.random.default_rng(ocfg.seed)
    key = jax.random.PRNGKey(ocfg.seed)
    est_tp = np.zeros((s_slots, t_steps))
    rmse = np.zeros(t_steps)
    adapted = np.zeros(t_steps, bool)
    train_loss: list = []
    ckpt_steps: list = []
    total_steps = 0
    outs = []
    lat_rows = []
    st = st0
    with ctx:
        for t in range(t_steps):
            sid0 = int(st.next_arrival) if telemetry is not None else 0
            st, lat = programs.admit(st, jnp.asarray(t, I32),
                                     jnp.asarray(int(ready[t]), I32),
                                     arrival_d, warm_d)
            lat_rows.append(np.asarray(lat))
            kpms_t, iq_t, alloc_t, tp_t, act_m = programs.gather(
                st, wins_d, iq_d, alloc_d, true_d)
            if serving is not None:
                kpms_t = sh.put(kpms_t, ("batch", None, None))
                iq_t = sh.put(iq_t, ("batch", None, None, None))
                alloc_t = sh.put(alloc_t, ("batch",))
                tp_t = sh.put(tp_t, ("batch",))
            with telmod.stage("estimator_fwd"):
                raw = np.asarray(predict_fn(params, kpms_t, iq_t, alloc_t))
            act_np = np.asarray(act_m)
            est_col = np.where(act_np,
                               np.clip(raw, tp_clip[0], tp_clip[1]), 0.0)
            est_tp[:, t] = est_col
            tp_np = np.asarray(tp_t)
            n_act = max(int(act_np.sum()), 1)
            rmse[t] = float(np.sqrt(
                np.sum(act_np * (est_col - tp_np) ** 2) / n_act))
            buf = buffer_add_masked(buf, kpms_t, iq_t, alloc_t, tp_t, act_m)
            fill = buffer_count(buf)
            dstate, fired = drift_step(ocfg.drift, dstate, rmse[t],
                                       armed=fill >= ocfg.min_fill)
            if telemetry is not None:
                telemetry.drift(t, bool(fired), rmse[t],
                                drift_threshold(ocfg.drift, dstate),
                                n_triggers=int(dstate.n_triggers))
            if fired:
                data = buffer_data(buf)
                burst = []
                with telmod.stage("online_burst"):
                    for _ in range(ocfg.steps):
                        idx = jnp.asarray(rng.integers(0, fill, ocfg.batch),
                                          I32)
                        key, sub = jax.random.split(key)
                        params, opt_state, loss = step_fn(params, opt_state,
                                                          data, idx, sub)
                        burst.append(float(loss))
                    if serving is not None:
                        with telmod.stage("weight_swap"):
                            params = replicate_params(serving, params)
                total_steps += ocfg.steps
                train_loss.append(float(np.mean(burst)))
                adapted[t] = True
                if telemetry is not None:
                    telemetry.burst(t, ocfg.steps, float(np.mean(burst)),
                                    serving is not None)
                if mgr is not None:
                    mgr.save(dstate.n_triggers, params)
                    ckpt_steps.append(dstate.n_triggers)
            st, ys = programs.serve_retire(
                st, tables_d, jnp.asarray(est_col, F32), true_d, cell_d,
                dwell_d)
            outs.append([np.asarray(y) for y in ys])
            if telemetry is not None:
                o = outs[-1]
                eff = (np.maximum(tp_np * np.clip(o[4], 0.0, 1.0),
                                  tpmod.PRB_FLOOR_MBPS)
                       if tel_sched else None)
                telemetry.update(
                    period=t, split=o[3], est=est_col, true=tp_np,
                    share=o[4], active=o[0], dconst=tel_dconst,
                    dbytes=tel_dbytes, eff=eff,
                    admit_sid=sid0 + np.arange(lat_rows[-1].shape[0]),
                    admit_lat=lat_rows[-1], n_depart=o[5])
    if mgr is not None:
        mgr.wait()
    stats = OnlineStats(rmse=rmse, adapted=adapted,
                        n_adaptations=int(adapted.sum()),
                        train_steps=total_steps, train_loss=train_loss,
                        buffer_fill=buffer_count(buf),
                        threshold_mbps=drift_threshold(ocfg.drift, dstate),
                        params=params, ckpt_steps=ckpt_steps)
    act_ts, sid_ts, age_ts, split_ts, share_ts, dep_ts = (
        np.stack([o[i] for o in outs]) for i in range(6))
    lat_ts = np.stack(lat_rows)
    return ((act_ts, sid_ts, age_ts, split_ts, share_ts, lat_ts, dep_ts),
            est_tp, stats)


def _online_pool_run_ssm(sessions, schedule, estimator, ocfg, programs, st0,
                         tables_d, warm_d, true_d, cell_d, dwell_d,
                         arrival_d, *, serving=None, tp_clip=TP_CLIP_MBPS,
                         telemetry=None, tel_dconst=None, tel_dbytes=None,
                         tel_sched=False):
    """The recurrent closed-loop arm of ``simulate_pool``.

    Slots carry per-slot SSD states alongside the controller states. On
    admission a slot's state is reset to its session's *warmed* state —
    ``ssm_warm_state`` over the trace's WINDOW - 1 warmup reports,
    precomputed for every session in one sequence pass and recomputed
    after each adaptation burst so later admits warm with the weights
    that will serve them (bursts are rare; live slots are NOT re-warmed —
    the recurrence forgets old-weight history at its trained decay, see
    ``sim.online._online_estimate_fleet_ssm``). Each period is then one
    O(1) ``ssm_step`` over the capacity axis, masked ring-ingest of
    (pre-report state, report, label) events, and the shared drift/burst
    machinery."""
    import contextlib

    from repro.checkpoint import CheckpointManager
    from repro.dist import sharding as sh
    from repro.estimator.ssm import (episode_features, reduce_forecasts,
                                     ssm_state_init, ssm_step,
                                     ssm_warm_state)
    from repro.optim import AdamW
    from repro.sim.online import (OnlineStats, buffer_add_ssm, buffer_count,
                                  buffer_data, buffer_init, drift_init,
                                  drift_step, drift_threshold,
                                  online_step_program)
    from repro.sim.serving import (STATE_AXES, replicate_params,
                                   ssm_serving_program)

    c, params = estimator
    if sessions.kpms is None:
        raise ValueError("the recurrent estimator needs raw KPM reports: "
                         "generate sessions with include_kpms=True")
    if c.include_iq and sessions.iq is None:
        raise ValueError("SSMConfig(include_iq=True) needs spectrogram "
                         "snapshots: generate sessions with "
                         "include_iq=True")
    s_slots = int(st0.active.shape[0])
    if int(ocfg.capacity) < s_slots:
        raise ValueError(
            f"OnlineConfig.capacity ({ocfg.capacity}) must cover the pool "
            f"capacity ({s_slots}) for masked ingestion")
    t_steps = schedule.horizon
    feats_np = episode_features(sessions.kpms, sessions.alloc_ratio,
                                sessions.iq if c.include_iq else None)
    feats_d = jnp.asarray(feats_np)  # (M, L + WINDOW, F)
    warm_prefix = jnp.asarray(feats_np[:, :WINDOW - 1])
    ready = np.asarray(schedule.ready_end, np.int64)
    opt = AdamW(lr=ocfg.lr, weight_decay=ocfg.weight_decay,
                clip_norm=ocfg.clip_norm)
    opt_state = opt.init(params)
    step_fn = online_step_program(c, opt, serving)
    if serving is not None:
        predict_fn = ssm_serving_program(c, serving)
        params = replicate_params(serving, params)
        ctx = sh.use_rules(serving.mesh, serving.rule_overrides())
    else:
        predict_fn = functools.partial(ssm_step, c)
        ctx = contextlib.nullcontext()
    mgr = (CheckpointManager(ocfg.ckpt_dir, keep=ocfg.ckpt_keep)
           if ocfg.ckpt_dir else None)
    buf = buffer_init(ocfg.capacity, c, serving=serving,
                      quant=ocfg.ring_quant)
    dstate = drift_init()
    rng = np.random.default_rng(ocfg.seed)
    key = jax.random.PRNGKey(ocfg.seed)
    est_tp = np.zeros((s_slots, t_steps))
    rmse = np.zeros(t_steps)
    adapted = np.zeros(t_steps, bool)
    train_loss: list = []
    ckpt_steps: list = []
    total_steps = 0
    outs = []
    lat_rows = []
    st = st0
    with ctx:
        def place(x, axes):
            return sh.put(jnp.asarray(x, F32), axes)

        warm_all = ssm_warm_state(c, params, warm_prefix)  # (M, ...)
        slot_state = place(ssm_state_init(c, (s_slots,)), STATE_AXES)
        for t in range(t_steps):
            sid0 = int(st.next_arrival) if telemetry is not None else 0
            st, lat = programs.admit(st, jnp.asarray(t, I32),
                                     jnp.asarray(int(ready[t]), I32),
                                     arrival_d, warm_d)
            lat_rows.append(np.asarray(lat))
            fresh = st.active & (st.age == 0)  # admitted this period
            slot_state = _ssm_slot_reset(slot_state, warm_all, st.sid,
                                         fresh)
            feats_t, tp_t = _ssm_pool_gather(st.active, st.sid, st.age,
                                             feats_d, true_d)
            if serving is not None:
                slot_state = sh.put(slot_state, STATE_AXES)
                feats_t = place(feats_t, ("batch", None))
                tp_t = place(tp_t, ("batch",))
            state_prev = slot_state
            with telmod.stage("estimator_fwd"):
                slot_state, fc = predict_fn(params, slot_state, feats_t)
                fc = np.asarray(fc)
            act_np = np.asarray(st.active)
            cur = np.clip(fc[:, 0], tp_clip[0], tp_clip[1])
            est_col = np.where(
                act_np, np.clip(reduce_forecasts(c, fc),
                                tp_clip[0], tp_clip[1]), 0.0)
            est_tp[:, t] = est_col
            tp_np = np.asarray(tp_t)
            n_act = max(int(act_np.sum()), 1)
            rmse[t] = float(np.sqrt(
                np.sum(act_np * (cur - tp_np) ** 2) / n_act))
            buf = buffer_add_ssm(buf, state_prev, feats_t, tp_t,
                                 mask=st.active)
            fill = buffer_count(buf)
            dstate, fired = drift_step(ocfg.drift, dstate, rmse[t],
                                       armed=fill >= ocfg.min_fill)
            if telemetry is not None:
                telemetry.drift(t, bool(fired), rmse[t],
                                drift_threshold(ocfg.drift, dstate),
                                n_triggers=int(dstate.n_triggers))
            if fired:
                data = buffer_data(buf)
                burst = []
                with telmod.stage("online_burst"):
                    for _ in range(ocfg.steps):
                        idx = jnp.asarray(rng.integers(0, fill, ocfg.batch),
                                          I32)
                        key, sub = jax.random.split(key)
                        params, opt_state, loss = step_fn(params, opt_state,
                                                          data, idx, sub)
                        burst.append(float(loss))
                    if serving is not None:
                        with telmod.stage("weight_swap"):
                            params = replicate_params(serving, params)
                # future admits warm with the weights that will serve them
                warm_all = ssm_warm_state(c, params, warm_prefix)
                total_steps += ocfg.steps
                train_loss.append(float(np.mean(burst)))
                adapted[t] = True
                if telemetry is not None:
                    telemetry.burst(t, ocfg.steps, float(np.mean(burst)),
                                    serving is not None)
                if mgr is not None:
                    mgr.save(dstate.n_triggers, params)
                    ckpt_steps.append(dstate.n_triggers)
            st, ys = programs.serve_retire(
                st, tables_d, jnp.asarray(est_col, F32), true_d, cell_d,
                dwell_d)
            outs.append([np.asarray(y) for y in ys])
            if telemetry is not None:
                o = outs[-1]
                eff = (np.maximum(tp_np * np.clip(o[4], 0.0, 1.0),
                                  tpmod.PRB_FLOOR_MBPS)
                       if tel_sched else None)
                telemetry.update(
                    period=t, split=o[3], est=est_col, true=tp_np,
                    share=o[4], active=o[0], dconst=tel_dconst,
                    dbytes=tel_dbytes, eff=eff,
                    admit_sid=sid0 + np.arange(lat_rows[-1].shape[0]),
                    admit_lat=lat_rows[-1], n_depart=o[5])
    if mgr is not None:
        mgr.wait()
    stats = OnlineStats(rmse=rmse, adapted=adapted,
                        n_adaptations=int(adapted.sum()),
                        train_steps=total_steps, train_loss=train_loss,
                        buffer_fill=buffer_count(buf),
                        threshold_mbps=drift_threshold(ocfg.drift, dstate),
                        params=params, ckpt_steps=ckpt_steps)
    act_ts, sid_ts, age_ts, split_ts, share_ts, dep_ts = (
        np.stack([o[i] for o in outs]) for i in range(6))
    lat_ts = np.stack(lat_rows)
    return ((act_ts, sid_ts, age_ts, split_ts, share_ts, lat_ts, dep_ts),
            est_tp, stats)
