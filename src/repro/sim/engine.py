"""Fleet-scale adaptive-splitting simulation engine.

Runs N UEs x S scenarios through the paper's full adaptive path —
channel -> KPM/IQ -> throughput estimate -> EWMA/hysteresis controller ->
PSO lookup -> split metrics — as one vectorized program:

  * episodes come in as an ``EpisodeBatch`` ((N, T, ...) arrays, see
    ``repro.channel.scenarios.gen_episode_batch``),
  * the whole fleet's throughput predictions come from a single estimator
    ``predict`` call per 0.1 s report period,
  * the N controllers advance as ``vmap(controller_step)`` inside one
    ``lax.scan`` over report periods,
  * delay/privacy/energy are gathered for the fleet in one indexing pass.

``simulate_fleet_looped`` is the legacy per-UE, per-step Python loop kept
as the equivalence reference and speedup baseline; both paths produce
bit-identical split decisions (they share ``controller_step``) and
float-identical metrics.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.channel import kpm as kpmmod
from repro.channel import throughput as tpmod
from repro.channel.scenarios import SCENARIOS, WINDOW, EpisodeBatch
from repro.core.controller import (AdaptiveSplitController, ControllerConfig,
                                   controller_init, controller_step)
from repro.core.energy import EDGE_A40X2, UE_VM_2CORE, DeviceProfile
from repro.core.objective import Constraints, Weights, evaluate
from repro.core.profiles import SplitProfile
# the estimator clamp range is part of the PSO sweep config, not ours
from repro.core.pso import TP_CLIP_MBPS, LookupTable, StackedLookupTable
from repro.estimator.serve import check_quant, fwd_int8, quantize_estimator
from repro.estimator.ssm import (SSMConfig, episode_features,
                                 reduce_forecasts, ssm_forward_seq)
from repro.estimator.train import fwd
from repro.kernels.featurize import kpm_feature_windows
from repro.sim import telemetry as telmod
from repro.sim.sched import SchedulerConfig, scheduler_init, scheduler_step
from repro.sim.serving import (ServingMesh, sharded_fleet_estimate,
                               sharded_ssm_estimate)
from repro.sim.telemetry import TelemetryConfig


@dataclasses.dataclass
class FleetResult:
    """Per-UE, per-report-period outcome of a fleet simulation."""

    splits: np.ndarray  # (N, T) int32 — deployed split per period
    true_tp: np.ndarray  # (N, T) Mbps ground truth
    est_tp: np.ndarray  # (N, T) Mbps fed to the controllers
    delay_s: np.ndarray  # (N, T) E2E delay at the deployed split
    privacy: np.ndarray  # (N, T) dCor leak at the deployed split
    energy_j: np.ndarray  # (N, T) UE energy at the deployed split
    fixed: Optional["FleetResult"] = None  # fixed-split baseline, same shapes
    prb_share: Optional[np.ndarray] = None  # (N, T) gNB PRB grant, if
    # a scheduler ran; None on the default (uncontended) path
    online: Optional[object] = None  # sim.online.OnlineStats when the run
    # adapted the estimator online; None on the default (frozen) path
    active: Optional[np.ndarray] = None  # (N, T) bool slot-occupancy mask
    # when the run churned (rows are pool slots, not fixed UEs); None on
    # the batch-synchronous path, where every (u, t) cell is live
    lifecycle: Optional[object] = None  # sim.pool.LifecycleStats when the
    # run churned (admissions, departures, admission latency); else None
    telemetry: Optional[object] = None  # sim.telemetry.TelemetryRecord when
    # the run was passed ``telemetry=TelemetryConfig(...)``; None (and the
    # traced programs untouched) on the default path

    @property
    def n_ues(self) -> int:
        return self.splits.shape[0]

    @property
    def n_steps(self) -> int:
        return self.splits.shape[1]

    def scenario_means(self, scenario_idx: np.ndarray) -> dict:
        """Per-scenario (delay, privacy, energy) means, keyed by name."""
        out = {}
        for i in np.unique(np.asarray(scenario_idx)):
            rows = scenario_idx == i
            name = SCENARIOS[i] if 0 <= i < len(SCENARIOS) else str(i)
            out[name] = np.array([self.delay_s[rows].mean(),
                                  self.privacy[rows].mean(),
                                  self.energy_j[rows].mean()])
        return out


# Transmission-delay guard: an idle slot or a zero-PRB grant has no link,
# and dividing by its 0 bps would poison delay means with inf. Any real
# link is floored far above this (``throughput.max_throughput_mbps`` never
# drops below 0.5 Mbps and PRB scaling below ``PRB_FLOOR_MBPS`` = 0.01
# Mbps = 1e4 bps), so clamping at 1 bps is bit-invisible to live traffic.
TP_FLOOR_BPS = 1.0


def split_metrics(profile: SplitProfile, splits: np.ndarray,
                  tp_mbps: np.ndarray, ue: DeviceProfile = UE_VM_2CORE,
                  server: DeviceProfile = EDGE_A40X2
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(delay_s, privacy, energy_j) for a whole fleet in one gather.

    Element-for-element identical to ``evaluate(...)`` at the chosen split
    (same operations in the same order, float64 throughout). Throughput is
    floored at ``TP_FLOOR_BPS`` so zero/near-zero rates yield huge-but-
    finite delays instead of inf/NaN."""
    l = np.asarray(splits)
    tp_bps = np.maximum(np.asarray(tp_mbps, float) * 1e6, TP_FLOOR_BPS)
    delay = (profile.d_ue(ue)[l] + profile.d_ser(server)[l]
             + profile.data_bytes[l] * 8.0 / tp_bps)
    return delay, profile.privacy[l], profile.e_ue(ue)[l]


@functools.lru_cache(maxsize=None)
def _sweep_fn(ewma_alpha: float, hysteresis_steps: int, fallback_split: int,
              sched: Optional[SchedulerConfig] = None, n_cells: int = 1,
              telem: Optional[TelemetryConfig] = None):
    """Compiled fleet sweep, cached per controller (+ scheduler) config
    (jit's own cache then handles distinct fleet shapes).

    Without a scheduler this is the PR-2 program, untouched: controllers
    consume the estimates as-is. With one, the gNB scheduler runs *inside*
    the scan so allocation, estimation and splitting co-evolve: each
    period the scheduler divides every cell's PRB budget over its attached
    UEs (PF state carried across periods), and each controller sees its
    estimate scaled by the share it was actually granted.

    ``telem`` (default None) selects the telemetry variant: the same scan
    additionally carries a ``TelemetryState``, folding each period's
    splits / error / delay / shares into it via ``telemetry_step`` and
    (on the scheduled arm) logging cell-index changes as handover events.
    ``telem=None`` returns the exact prior programs — the telemetry
    variants are *separate* cache entries, never a branch inside the
    default trace."""
    cfg = ControllerConfig(ewma_alpha, hysteresis_steps, fallback_split)
    step = functools.partial(controller_step, cfg=cfg)

    if sched is None:
        if telem is None:
            @jax.jit
            def sweep(tab, warm, est):
                init = controller_init(warm, batch_shape=tab.shape[:1])

                def body(state, tp_t):
                    return jax.vmap(step)(tab, state, tp_t)

                _, splits = lax.scan(body, init, est.T)
                return splits.T

            return sweep

        @jax.jit
        def sweep_telem(tab, warm, est, true, dconst, dbytes, ts0):
            init = (controller_init(warm, batch_shape=tab.shape[:1]), ts0)
            ones = jnp.ones(tab.shape[:1], jnp.float32)
            live = jnp.ones(tab.shape[:1], bool)

            def body(carry, xs):
                ctl, ts = carry
                est_t, true_t, t = xs
                with jax.named_scope("controller_step"):
                    ctl, split = jax.vmap(step)(tab, ctl, est_t)
                with jax.named_scope("telemetry_step"):
                    ts, row = telmod.telemetry_step(
                        telem, ts, period=t, split=split, est_tp=est_t,
                        true_tp=true_t, share=ones, active=live,
                        dconst=dconst, dbytes=dbytes)
                return (ctl, ts), (split, row)

            (_, ts), (splits, rows) = lax.scan(
                body, init,
                (est.T, true.T, jnp.arange(est.shape[1], dtype=jnp.int32)))
            return splits.T, ts, rows

        return sweep_telem

    if telem is None:
        @jax.jit
        def sweep_scheduled(tab, warm, est, rate, cells):
            init = (controller_init(warm, batch_shape=tab.shape[:1]),
                    scheduler_init(tab.shape[0]))

            def body(carry, xs):
                ctl, ss = carry
                est_t, rate_t, cell_t = xs
                ss, share = scheduler_step(sched, n_cells, ss, cell_t, rate_t)
                ctl, split = jax.vmap(step)(tab, ctl, est_t * share)
                return (ctl, ss), (split, share)

            _, (splits, shares) = lax.scan(body, init,
                                           (est.T, rate.T, cells.T))
            return splits.T, shares.T

        return sweep_scheduled

    @jax.jit
    def sweep_scheduled_telem(tab, warm, est, rate, cells, dconst, dbytes,
                              ts0):
        init = (controller_init(warm, batch_shape=tab.shape[:1]),
                scheduler_init(tab.shape[0]), ts0, cells[:, 0])

        def body(carry, xs):
            ctl, ss, ts, prev_cell = carry
            est_t, rate_t, cell_t, t = xs
            with jax.named_scope("scheduler_step"):
                ss, share = scheduler_step(sched, n_cells, ss, cell_t, rate_t)
            with jax.named_scope("controller_step"):
                ctl, split = jax.vmap(step)(tab, ctl, est_t * share)
            with jax.named_scope("telemetry_step"):
                # what split_metrics sees: PRB-scaled, floored throughput
                eff = jnp.maximum(rate_t * jnp.clip(share, 0.0, 1.0),
                                  tpmod.PRB_FLOOR_MBPS)
                hand = (cell_t != prev_cell).sum(dtype=jnp.int32)
                ts, row = telmod.telemetry_step(
                    telem, ts, period=t, split=split, est_tp=est_t,
                    true_tp=rate_t, eff_tp=eff, share=share,
                    active=jnp.ones(tab.shape[:1], bool), dconst=dconst,
                    dbytes=dbytes, n_handover=hand)
            return (ctl, ss, ts, cell_t), (split, share, row)

        (_, _, ts, _), (splits, shares, rows) = lax.scan(
            body, init,
            (est.T, rate.T, cells.T,
             jnp.arange(est.shape[1], dtype=jnp.int32)))
        return splits.T, shares.T, ts, rows

    return sweep_scheduled_telem


def run_controllers(tables: np.ndarray, est_tp: np.ndarray,
                    cfg: ControllerConfig, warm_split) -> np.ndarray:
    """(N, T) splits: N controllers over T periods as one vmap+scan.

    ``tables``: (N, tp_max+1) stacked lookup rows (``StackedLookupTable
    .tables``); ``warm_split``: scalar or (N,) deployed-split warm start."""
    sweep = _sweep_fn(cfg.ewma_alpha, cfg.hysteresis_steps,
                      cfg.fallback_split)
    return np.asarray(sweep(
        jnp.asarray(tables, jnp.int32), jnp.asarray(warm_split, jnp.int32),
        jnp.asarray(est_tp, jnp.float32)))


def run_scheduled(tables: np.ndarray, est_tp: np.ndarray,
                  cfg: ControllerConfig, warm_split,
                  sched: SchedulerConfig, n_cells: int, cell_idx: np.ndarray,
                  rate_mbps: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """((N, T) int32 splits, (N, T) float PRB shares in [0, 1]): scheduler
    + controllers in one scan.

    ``tables``: (N, tp_max+1) stacked lookup rows; ``est_tp``: (N, T)
    estimated full-grant throughput in Mbps (each controller consumes
    ``est_tp * share``); ``cell_idx``: (N, T) cell of each UE per period
    (inter-cell handover = the index changing mid-episode);
    ``rate_mbps``: (N, T) the gNB's CQI view (full-grant achievable rate,
    Mbps) driving the scheduler. This is the ``sched is not None`` arm of
    ``simulate_fleet``; with ``sched=None`` the engine takes
    ``run_controllers`` instead, whose program is bit-identical to PR 2.
    """
    sweep = _sweep_fn(cfg.ewma_alpha, cfg.hysteresis_steps,
                      cfg.fallback_split, sched, int(n_cells))
    splits, shares = sweep(
        jnp.asarray(tables, jnp.int32), jnp.asarray(warm_split, jnp.int32),
        jnp.asarray(est_tp, jnp.float32), jnp.asarray(rate_mbps, jnp.float32),
        jnp.asarray(cell_idx, jnp.int32))
    return np.asarray(splits), np.asarray(shares)


def _run_controllers_telem(tables, est_tp, true_tp, cfg: ControllerConfig,
                           warm_split, tcfg: TelemetryConfig, dconst, dbytes,
                           ts0):
    """``run_controllers`` with the metric plane carried through the scan:
    also returns the final ``TelemetryState`` and the stacked per-period
    rows. The public entry point stays untouched — the telemetry variant
    is a distinct compiled program."""
    sweep = _sweep_fn(cfg.ewma_alpha, cfg.hysteresis_steps,
                      cfg.fallback_split, telem=tcfg)
    splits, ts, rows = sweep(
        jnp.asarray(tables, jnp.int32), jnp.asarray(warm_split, jnp.int32),
        jnp.asarray(est_tp, jnp.float32), jnp.asarray(true_tp, jnp.float32),
        dconst, dbytes, ts0)
    return np.asarray(splits), ts, rows


def _run_scheduled_telem(tables, est_tp, cfg: ControllerConfig, warm_split,
                         sched: SchedulerConfig, n_cells: int, cell_idx,
                         rate_mbps, tcfg: TelemetryConfig, dconst, dbytes,
                         ts0):
    """``run_scheduled`` with the metric plane (handover events included)
    carried through the scan."""
    sweep = _sweep_fn(cfg.ewma_alpha, cfg.hysteresis_steps,
                      cfg.fallback_split, sched, int(n_cells), telem=tcfg)
    splits, shares, ts, rows = sweep(
        jnp.asarray(tables, jnp.int32), jnp.asarray(warm_split, jnp.int32),
        jnp.asarray(est_tp, jnp.float32), jnp.asarray(rate_mbps, jnp.float32),
        jnp.asarray(cell_idx, jnp.int32), dconst, dbytes, ts0)
    return np.asarray(splits), np.asarray(shares), ts, rows


def emit_period_samples(episode: EpisodeBatch, t: int,
                        wins: Optional[np.ndarray] = None, *,
                        trace: Optional[np.ndarray] = None) -> dict:
    """The (kpms, iq, alloc -> measured tp) sample batch report period
    ``t`` emits: N rows of estimator inputs plus the period's *measured*
    throughput in Mbps — the label the fleet observes for free after
    acting, which is what the online replay buffer (``repro.sim.online``)
    ingests and what ``estimate_fleet`` feeds the estimator (``predict``
    only reads ``tp`` for its length).

    ``wins``: optionally the precomputed float32
    ``episode.kpm_windows(normalize=True)`` so per-period callers amortize
    the window view across the episode. ``trace``: the fused-featurize
    alternative — the (N, T + WINDOW, 15) *normalized* float32 KPM trace;
    period ``t``'s window is then the ``trace[:, t:t+WINDOW]`` view, the
    same f32 elements as ``wins[:, t]`` without ever materializing the
    (N, T, WINDOW, 15) tensor."""
    if trace is not None:
        kp = trace[:, t:t + WINDOW]
    else:
        if wins is None:
            wins = episode.kpm_windows(normalize=True).astype(np.float32)
        kp = wins[:, t]
    return {"kpms": kp,
            "iq": episode.iq[:, t].astype(np.float32),
            "alloc": episode.alloc_ratio.astype(np.float32),
            "tp": episode.tp_mbps[:, t].astype(np.float32)}


# Rows per fused estimator forward on the unsharded path: bounds the f32
# activation working set (8192 rows of the default (2, 64, 14) IQ is
# ~56 MB) while amortizing dispatch over many report periods per call.
EST_CHUNK_ROWS = 8192


def estimate_fleet(episode: EpisodeBatch, estimator, tp_clip=TP_CLIP_MBPS,
                   *, serving: Optional[ServingMesh] = None,
                   quant: Optional[str] = None,
                   fused: bool = False) -> np.ndarray:
    """(N, T) estimated throughput in Mbps, clipped into ``tp_clip``.

    Batched inference over the fleet (the AF's batch path): period ``t``
    sees each UE's (WINDOW, 15) KPM window ending just before ``t`` plus
    its (2, n_sc, 14) IQ spectrogram, and the fused prediction is clipped
    into the PSO sweep range (Mbps, default ``TP_CLIP_MBPS``). The
    unsharded path vectorizes *across report periods* too: as many whole
    periods as fit in ``EST_CHUNK_ROWS`` rows are flattened into one
    jitted forward (periods x fleet rows), so a T-period episode costs
    ``ceil(N * T / EST_CHUNK_ROWS)`` dispatches instead of T — the numbers
    are identical to the old per-period loop because the forward is
    row-wise (pinned by ``tests/test_sim_fleet.py``).

    ``estimator``: an ``(EstimatorConfig, params)`` pair, or an
    ``(SSMConfig, params)`` pair — the recurrent estimator
    (``repro.estimator.ssm``), which consumes the raw KPM report stream
    (no IQ, no windows) through one chunked SSD sequence pass and emits
    policy-reduced forecast estimates; ``fused`` is then a no-op (there
    is no window featurize to fuse) and ``quant`` must be None (the
    recurrent path serves fp32). ``serving``: an
    optional ``repro.sim.serving.ServingMesh``; when given, each period's
    forward runs as the mesh-sharded SPMD program — UE batch sharded over
    the mesh's data axis, weights replicated — instead of the
    single-device ``predict`` path. Both paths compute the same per-UE
    math; they are pinned allclose by ``tests/test_serving_mesh.py``.

    ``fused=True`` replaces the host stride-trick window materialization
    — a WINDOW x blowup of the whole KPM trace — with the fused featurize
    path: per chunk, the ``kernels/featurize`` Pallas kernel normalizes
    and windows the raw trace on device (under a serving mesh, the
    equivalent per-period trace *view*, which is bit-identical to the
    unfused elements). ``quant="int8"`` serves ``quantize_estimator``
    weights through the int8 kernels (``estimator.serve``). Both default
    off; ``fused=False, quant=None`` is the exact prior program (pinned
    by ``tests/test_sim_fused.py``).
    """
    ecfg, params = estimator
    if isinstance(ecfg, SSMConfig):
        return _estimate_fleet_ssm(episode, ecfg, params, tp_clip,
                                   serving=serving, quant=quant)
    check_quant(quant)
    if fused and episode.kpms is None:
        raise ValueError("fused featurize needs raw KPM reports: generate "
                         "the episode with include_kpms=True")
    if episode.iq is None:
        raise ValueError(
            "estimator inference needs IQ spectrograms: generate the episode "
            "with include_iq=True")
    n, t_steps = episode.n_ues, episode.n_steps
    alloc = episode.alloc_ratio.astype(np.float32)
    if serving is not None:
        if fused:
            # normalized trace, windowed per period as a view (the f64
            # normalize + f32 cast matches kpm_windows bit-for-bit)
            trace = kpmmod.normalize_kpms(episode.kpms).astype(np.float32)
            return sharded_fleet_estimate(ecfg, params, trace, episode.iq,
                                          alloc, serving, tp_clip,
                                          quant=quant, window=WINDOW)
        wins = episode.kpm_windows(normalize=True).astype(np.float32)
        return sharded_fleet_estimate(ecfg, params, wins, episode.iq,
                                      alloc, serving, tp_clip, quant=quant)
    qparams = quantize_estimator(params) if quant == "int8" else None
    if fused:
        kpms_d = jnp.asarray(episode.kpms, jnp.float32)
        center = jnp.asarray(kpmmod.KPM_CENTER)
        scale = jnp.asarray(kpmmod.KPM_SCALE)
    else:
        with telmod.stage("featurize"):
            wins = episode.kpm_windows(normalize=True).astype(np.float32)
    est = np.empty((n, t_steps))
    periods = max(1, min(t_steps, EST_CHUNK_ROWS // max(n, 1)))
    for t0 in range(0, t_steps, periods):
        b = min(periods, t_steps - t0)
        sl = slice(t0, t0 + b)
        rows = n * b
        # (N, b, ...) -> (N*b, ...): row (u * b + j) is UE u at period t0+j
        if fused:
            with telmod.stage("featurize"):
                # window j of the chunk covers trace steps
                # [t0+j, t0+j+WINDOW)
                kw = kpm_feature_windows(kpms_d[:, t0:t0 + b + WINDOW - 1],
                                         center, scale, WINDOW)
                kpms_rows = kw.reshape(rows, WINDOW, kw.shape[-1])
        else:
            kpms_rows = jnp.asarray(np.ascontiguousarray(wins[:, sl]).reshape(
                rows, *wins.shape[2:]))
        iq_rows = jnp.asarray(np.asarray(episode.iq[:, sl],
                                         np.float32).reshape(
            rows, *episode.iq.shape[2:]))
        alloc_rows = jnp.asarray(np.repeat(alloc, b))
        with telmod.stage("estimator_fwd"):
            if quant == "int8":
                out = fwd_int8(ecfg, qparams, kpms_rows, iq_rows, alloc_rows)
            else:
                out = fwd(ecfg, params, kpms_rows, iq_rows, alloc_rows)
        est[:, sl] = np.asarray(out).reshape(n, b)
    return np.clip(est, tp_clip[0], tp_clip[1])


def _estimate_fleet_ssm(episode: EpisodeBatch, ecfg: SSMConfig, params,
                        tp_clip, *, serving: Optional[ServingMesh] = None,
                        quant: Optional[str] = None) -> np.ndarray:
    """The recurrent arm of :func:`estimate_fleet`: the whole (N, T +
    WINDOW) report stream runs through one chunked SSD sequence pass per
    ``EST_CHUNK_ROWS`` UEs (the first WINDOW - 1 reports warm the state,
    matching the windowed path's label alignment), and the (K+1)
    forecasts collapse to the policy estimate. Under a ``serving`` mesh
    the same math runs as the per-period O(1) step program, state
    sharded over the batch axis (pinned allclose by
    ``tests/test_estimator_ssm.py``)."""
    if quant is not None:
        raise ValueError("int8 serving applies to the windowed estimator; "
                         "the recurrent estimator serves fp32 weights")
    if episode.kpms is None:
        raise ValueError("the recurrent estimator needs raw KPM reports: "
                         "generate the episode with include_kpms=True")
    if ecfg.include_iq and episode.iq is None:
        raise ValueError("SSMConfig(include_iq=True) needs spectrogram "
                         "snapshots: generate the episode with "
                         "include_iq=True")
    n, t_steps = episode.n_ues, episode.n_steps
    feats = episode_features(episode.kpms, episode.alloc_ratio,
                             episode.iq if ecfg.include_iq else None)
    if serving is not None:
        return sharded_ssm_estimate(ecfg, params, feats, serving, tp_clip,
                                    n_periods=t_steps)
    est = np.empty((n, t_steps))
    for i in range(0, n, EST_CHUNK_ROWS):
        with telmod.stage("estimator_fwd"):
            fc, _ = ssm_forward_seq(ecfg, params,
                                    jnp.asarray(feats[i:i + EST_CHUNK_ROWS]))
        est[i:i + EST_CHUNK_ROWS] = reduce_forecasts(
            ecfg, np.asarray(fc[:, WINDOW - 1:WINDOW - 1 + t_steps]))
    return np.clip(est, tp_clip[0], tp_clip[1])


def simulate_fleet(episode: EpisodeBatch, table, profile: SplitProfile,
                   cfg: ControllerConfig, *, warm_split=None, estimator=None,
                   serving: Optional[ServingMesh] = None,
                   online=None,
                   fixed_split: Optional[int] = None,
                   ue: DeviceProfile = UE_VM_2CORE,
                   server: DeviceProfile = EDGE_A40X2,
                   sched: Optional[SchedulerConfig] = None,
                   cell_idx: Optional[np.ndarray] = None,
                   n_cells: int = 1,
                   churn=None, capacity: Optional[int] = None,
                   quant: Optional[str] = None,
                   fused: bool = False,
                   telemetry: Optional[TelemetryConfig] = None
                   ) -> FleetResult:
    """Vectorized fleet simulation (the production path).

    Consumes an ``EpisodeBatch`` of N UEs over T report periods (0.1 s
    each) and returns a ``FleetResult`` of (N, T) arrays: int32 split
    decisions, throughputs in Mbps, E2E delay in seconds, dCor privacy
    leakage in [0, 1], and per-inference UE energy in joules.

    ``table``: one ``LookupTable`` shared by the fleet or a
    ``StackedLookupTable`` with one row per UE. ``warm_split`` defaults to
    ``fixed_split`` (the AF streams reports before this window) or NO_SPLIT.
    ``estimator``: optional (EstimatorConfig, params); without it the
    controllers see the ground-truth throughput. ``serving``: optional
    ``repro.sim.serving.ServingMesh`` forwarded to ``estimate_fleet`` so
    the per-period estimator inference runs mesh-sharded (ignored without
    an ``estimator``). ``fixed_split`` also attaches the fixed-policy
    baseline metrics as ``result.fixed``.

    ``online`` (default None): a ``repro.sim.online.OnlineConfig`` closes
    the estimate->act->observe->learn loop — the per-report-period
    estimator forward runs with *continually adapted* weights: each
    period's measured throughput is ring-ingested as a free training
    label, a drift monitor watches the estimator RMSE, and when it trips
    the online trainer runs K jitted AdamW steps on the replay buffer
    (under the serving mesh when one is given) before the next period's
    predict. The resulting ``FleetResult.online`` carries the adaptation
    trace (per-period RMSE, bursts, checkpoints). Requires ``estimator``.

    ``sched`` (default None): a ``SchedulerConfig`` puts a gNB PRB
    scheduler inside the scan. ``cell_idx`` (N, T) assigns each UE to one
    of ``n_cells`` cells per period; every UE's throughput — the estimate
    its controller consumes and the ground truth its metrics are gathered
    at — is scaled by the PRB share the scheduler granted it (see
    ``repro.sim.cells`` for the orchestration layer).

    ``churn`` (default None): a ``repro.channel.scenarios.ChurnSchedule``
    switches the engine to the slot-pool path (``repro.sim.pool``): the
    episode's N rows become *sessions* that arrive, live for their dwell,
    and depart, served from a fixed ``capacity``-slot device-resident
    pool. Rows of the result are then pool slots over time, with
    ``result.active`` marking occupancy and ``result.lifecycle`` carrying
    admission/departure stats; ``cell_idx`` is interpreted as a (N,)
    per-session static cell attach.

    Equivalence guarantee: with ``sched=None`` the scheduler hook is a
    strict no-op — the traced program is the PR-2 engine unchanged, split
    decisions are bit-identical and metrics float-identical to it (pinned
    by ``tests/test_sim_cells.py`` and the ``cells/noop_equivalence``
    benchmark record). Sharded serving does not weaken this: it changes
    where the estimator forward runs, not the controller scan. Likewise
    ``online=None`` (the default) never touches ``repro.sim.online`` —
    the estimates, splits and metrics are bit-identical to the PR 4
    engine (pinned by ``tests/test_sim_online.py``) — and ``churn=None``
    (the default) never touches ``repro.sim.pool``: the batch-synchronous
    path below is the PR 5 program unchanged (pinned by
    ``tests/test_sim_pool.py``).

    ``quant`` / ``fused`` (defaults None / False): the int8 serving and
    fused-featurize switches, forwarded to ``estimate_fleet`` (and the
    pool/online loops). They change how the per-period estimates are
    computed, never the controller scan; with the defaults the program is
    bit-identical to the PR 6 engine (pinned by
    ``tests/test_sim_fused.py``). ``quant`` requires a frozen estimator
    (the online trainer adapts fp32 weights).

    ``telemetry`` (default None): a ``repro.sim.telemetry.TelemetryConfig``
    turns on the in-scan metric plane — counters, running stats,
    fixed-bucket histograms and the typed event ring accumulate on device
    inside the controller scan (and the online loop logs drift/burst/swap
    events into the same ring), decoded once at run end into
    ``FleetResult.telemetry`` (a ``TelemetryRecord``). ``telemetry=None``
    never builds any of it: the traced programs, splits and metrics are
    bit-identical to the prior engine (pinned by
    ``tests/test_sim_telemetry.py``). ``TelemetryConfig(trace_dir=...)``
    additionally wraps the run in a ``jax.profiler.trace`` capture.
    """
    check_quant(quant)
    if online is not None and quant is not None:
        raise ValueError(
            "online adaptation serves the fp32 weights it trains; int8 "
            "serving (quant=...) needs a frozen estimator")
    if churn is not None:
        from repro.sim.pool import simulate_pool
        if capacity is None:
            raise TypeError("churn=... needs an explicit capacity=N_slots")
        return simulate_pool(episode, churn, table, profile, cfg,
                             capacity=capacity, warm_split=warm_split,
                             estimator=estimator, serving=serving,
                             online=online, fixed_split=fixed_split,
                             ue=ue, server=server, sched=sched,
                             cell=cell_idx, n_cells=n_cells,
                             quant=quant, fused=fused, telemetry=telemetry)
    tables = (table.tables if isinstance(table, StackedLookupTable)
              else np.broadcast_to(table.table,
                                   (episode.n_ues, len(table.table))))
    true_tp = np.asarray(episode.tp_mbps, float)
    tel = dconst = dbytes = None
    if telemetry is not None:
        tel = telmod.HostTelemetry(telemetry)
        dconst = jnp.asarray(np.asarray(profile.d_ue(ue))
                             + np.asarray(profile.d_ser(server)), jnp.float32)
        dbytes = jnp.asarray(profile.data_bytes, jnp.float32)
    online_stats = None
    rows = None
    with telmod.trace_capture(telemetry.trace_dir
                              if telemetry is not None else None):
        if online is not None:
            from repro.sim.online import online_estimate_fleet
            if estimator is None:
                raise ValueError("online adaptation needs an estimator")
            est_tp, online_stats = online_estimate_fleet(
                episode, estimator, online, serving=serving, fused=fused,
                telemetry=tel)
        else:
            est_tp = (estimate_fleet(episode, estimator, serving=serving,
                                     quant=quant, fused=fused)
                      if estimator is not None else true_tp)
        if warm_split is None:
            warm_split = (cfg.fallback_split if fixed_split is None
                          else fixed_split)
        if sched is None:
            shares, eff_tp = None, true_tp
            if telemetry is None:
                splits = run_controllers(tables, est_tp, cfg, warm_split)
            else:
                splits, tel.ts, rows = _run_controllers_telem(
                    tables, est_tp, true_tp, cfg, warm_split, telemetry,
                    dconst, dbytes, tel.ts)
        else:
            if cell_idx is None:
                raise ValueError("a scheduler needs a (N, T) cell_idx")
            if telemetry is None:
                splits, shares = run_scheduled(tables, est_tp, cfg,
                                               warm_split, sched, n_cells,
                                               cell_idx, true_tp)
            else:
                splits, shares, tel.ts, rows = _run_scheduled_telem(
                    tables, est_tp, cfg, warm_split, sched, n_cells,
                    cell_idx, true_tp, telemetry, dconst, dbytes, tel.ts)
            eff_tp = tpmod.prb_scaled_mbps(true_tp, shares)
            est_tp = est_tp * shares  # what the controllers consumed
    delay, priv, energy = split_metrics(profile, splits, eff_tp, ue, server)
    fixed = None
    if fixed_split is not None:
        fsplits = np.full_like(splits, fixed_split)
        fd, fp, fe = split_metrics(profile, fsplits, eff_tp, ue, server)
        fixed = FleetResult(fsplits, true_tp, est_tp, fd, fp, fe,
                            prb_share=shares)
    return FleetResult(splits, true_tp, est_tp, delay, priv, energy, fixed,
                       prb_share=shares, online=online_stats,
                       telemetry=tel.decode(rows) if tel is not None
                       else None)


def simulate_fleet_looped(episode: EpisodeBatch, table,
                          profile: SplitProfile, cfg: ControllerConfig, *,
                          warm_split=None, est_tp: Optional[np.ndarray] = None,
                          fixed_split: Optional[int] = None,
                          ue: DeviceProfile = UE_VM_2CORE,
                          server: DeviceProfile = EDGE_A40X2) -> FleetResult:
    """The legacy per-UE, per-report-period Python loop (pre-fleet fig6
    path): one ``AdaptiveSplitController`` per UE, one objective
    ``evaluate`` per UE per period. Kept as the equivalence reference and
    the speedup baseline for ``benchmarks/fleet.py``."""
    n, t_steps = episode.n_ues, episode.n_steps
    true_tp = np.asarray(episode.tp_mbps, float)
    if est_tp is None:
        est_tp = true_tp
    if warm_split is None:
        warm_split = cfg.fallback_split if fixed_split is None else fixed_split
    warm = np.broadcast_to(np.asarray(warm_split), (n,))
    splits = np.empty((n, t_steps), np.int32)
    acc = np.empty((n, t_steps, 3))
    facc = np.empty((n, t_steps, 3)) if fixed_split is not None else None
    for u in range(n):
        row = table.row(u) if isinstance(table, StackedLookupTable) else table
        ctl = AdaptiveSplitController(row, cfg)
        ctl.reset(warm_split=int(warm[u]))
        for t in range(t_steps):
            l = ctl.update(float(est_tp[u, t]))
            splits[u, t] = l
            terms = evaluate(profile, ue, server,
                             np.array([true_tp[u, t] * 1e6]),
                             Weights(1, 0, 0), Constraints())
            acc[u, t] = (terms.d_e2e[l, 0], profile.privacy[l], terms.e_ue[l])
            if facc is not None:
                facc[u, t] = (terms.d_e2e[fixed_split, 0],
                              profile.privacy[fixed_split],
                              terms.e_ue[fixed_split])
    fixed = None
    if facc is not None:
        fixed = FleetResult(np.full_like(splits, fixed_split), true_tp,
                            est_tp, facc[..., 0], facc[..., 1], facc[..., 2])
    return FleetResult(splits, true_tp, est_tp, acc[..., 0], acc[..., 1],
                       acc[..., 2], fixed)
