"""Vectorized gNB PRB schedulers (fluid / time-averaged model).

One 0.1 s estimator report period spans ~100 TTI-level scheduling rounds,
so what the fleet engine needs per period is each UE's *time-averaged*
share of its cell's PRB budget, not per-TTI grants. The three classic
policies are therefore modelled in their fluid limit: a per-UE weight,
normalized within each cell, is the fraction of the cell's ``n_prb``
budget the UE holds this period:

  rr      — round-robin: equal weights (equal time-share among attached).
  pf      — proportional-fair: w = r / max(avg, eps) with the classic
            EWMA of *served* throughput. Self-balancing: a UE whose
            average decays sees its weight grow, so no UE starves.
  maxsinr — max C/I: the whole budget goes to the cell's highest-rate
            UE(s); exact-rate ties split the budget equally. Starvation
            by design (the fairness counter-example in the sweep).

Everything is pure ``jnp`` on (N,) fleet arrays — cells are handled with
segment reductions over the (N,) cell-index vector, never a Python loop —
so ``scheduler_step`` drops straight into the engine's ``lax.scan`` body
and the whole multi-cell fleet advances as one vectorized program.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.ops import segment_max, segment_sum

from repro.kernels.segsum import segment_reduce

POLICIES = ("rr", "pf", "maxsinr")

F32 = jnp.float32
I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Static scheduler parameters (frozen: doubles as a jit cache key)."""

    policy: str = "rr"  # one of POLICIES
    n_prb: int = 100  # cell PRB budget per period (alloc = share * n_prb)
    pf_beta: float = 0.1  # EWMA weight of the newest served-rate sample
    eps: float = 1e-6  # floor for PF averages / empty-cell denominators
    fused: bool = False  # route the per-cell reductions through the
    # kernels/segsum Pallas kernel (one-hot compare in VMEM) instead of
    # XLA scatter-based segment_sum/segment_max; allclose to the default
    # (pinned by tests/test_kernels_fused.py / test_sim_fused.py)

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(
                f"unknown policy {self.policy!r}; pick one of {POLICIES}")
        if self.n_prb <= 0:
            raise ValueError(f"n_prb must be positive: {self.n_prb}")
        if not 0.0 < self.pf_beta <= 1.0:
            raise ValueError(f"pf_beta must be in (0, 1]: {self.pf_beta}")


class SchedulerState(NamedTuple):
    """Per-fleet scheduler state carried across report periods."""

    avg_tp: jax.Array  # (N,) f32 PF average of *served* throughput (Mbps)
    step: jax.Array  # i32, periods scheduled so far


def scheduler_init(n_ues: int, avg0: float = 1.0) -> SchedulerState:
    """Fresh state: neutral PF averages (no UE starts privileged)."""
    return SchedulerState(avg_tp=jnp.full((n_ues,), avg0, F32),
                          step=jnp.zeros((), I32))


def cell_shares(weights, cell_idx, n_cells: int, eps: float = 1e-6,
                fused: bool = False):
    """Normalize per-UE weights into per-cell PRB shares.

    ``share_u = w_u / sum_{v in cell(u)} w_v`` — shares sum to 1 over every
    non-empty cell (PRB conservation) and the computation is elementwise +
    segment sums, so it is permutation-equivariant in the UE axis.
    ``fused`` runs the normalizer sum as the ``kernels/segsum`` kernel."""
    w = jnp.asarray(weights, F32)
    denom = (segment_reduce(w, cell_idx, n_cells, op="sum") if fused
             else segment_sum(w, cell_idx, num_segments=n_cells))
    return w / jnp.maximum(denom[cell_idx], eps)


def scheduler_step(cfg: SchedulerConfig, n_cells: int, state: SchedulerState,
                   cell_idx, rate_mbps, active=None
                   ) -> tuple[SchedulerState, jax.Array]:
    """Advance the whole fleet's scheduler by one report period.

    ``cell_idx``: (N,) i32 cell of each UE this period (handover = the
    index changing between periods); ``rate_mbps``: (N,) the gNB's CQI
    view — each UE's max achievable rate at a full grant. Returns the new
    state and the (N,) PRB share granted to each UE.

    ``active``: optional (N,) bool slot mask for the churn engine. Masked
    rows get weight 0 and are redirected to a dummy segment ``n_cells``,
    so empty slots never receive PRBs, never shape a cell's normalizer or
    max-C/I winner, and their PF averages are held frozen (re-armed at
    admission). ``active=None`` is exactly the original fixed-fleet step.
    """
    r = jnp.asarray(rate_mbps, F32)
    cell_idx = jnp.asarray(cell_idx, I32)
    beta = F32(cfg.pf_beta)

    def seg_max(v, g, c):
        return (segment_reduce(v, g, c, op="max") if cfg.fused
                else segment_max(v, g, num_segments=c))

    if active is None:
        with jax.named_scope(f"sched_{cfg.policy}"):
            if cfg.policy == "rr":
                w = jnp.ones_like(r)
            elif cfg.policy == "pf":
                w = r / jnp.maximum(state.avg_tp, cfg.eps)
            else:  # maxsinr (validated in __post_init__)
                cmax = seg_max(r, cell_idx, n_cells)
                w = (r >= cmax[cell_idx]).astype(F32)
            share = cell_shares(w, cell_idx, n_cells, cfg.eps, cfg.fused)
            new = SchedulerState(
                avg_tp=(1 - beta) * state.avg_tp + beta * r * share,
                step=state.step + 1)
            return new, share
    with jax.named_scope(f"sched_{cfg.policy}_masked"):
        act = jnp.asarray(active, bool)
        actf = act.astype(F32)
        cell_m = jnp.where(act, cell_idx, n_cells)  # dummy segment: empties
        if cfg.policy == "rr":
            w = actf
        elif cfg.policy == "pf":
            w = actf * (r / jnp.maximum(state.avg_tp, cfg.eps))
        else:  # maxsinr
            cmax = seg_max(r, cell_m, n_cells + 1)
            w = ((r >= cmax[cell_m]) & act).astype(F32)
        share = cell_shares(w, cell_m, n_cells + 1, cfg.eps, cfg.fused)
        new = SchedulerState(
            avg_tp=jnp.where(act,
                             (1 - beta) * state.avg_tp + beta * r * share,
                             state.avg_tp),
            step=state.step + 1)
        return new, share
