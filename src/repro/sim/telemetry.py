"""Fleet telemetry: in-scan metrics, a device event log, stage tracing.

The fleet engine reacts to signals — split decisions, estimate error,
drift triggers, admission churn — that until now were only visible as
coarse per-run ``FleetResult`` arrays. This module makes per-period
fleet health a first-class, device-resident plane:

  * :class:`TelemetryState` — pure-jnp counters, running mean/min/max
    channels and fixed-bucket histograms (split index, estimate error,
    E2E delay, PRB share, occupancy), advanced by
    :func:`telemetry_step` *inside* the engine/pool ``lax.scan`` with
    mask-aware reductions: inactive slots are redirected to a dummy
    histogram bucket that ``mode="drop"`` discards, so one compiled
    update serves every occupancy level and nothing syncs to the host
    until the run ends;
  * :class:`EventRing` — a fixed-capacity device log of typed events
    (admission, departure, handover, drift trigger/recovery, online
    burst start/end, serving weight swap) with period stamps, written
    with the replay-ring scatter idiom. The ring keeps the *first*
    ``events_capacity`` events and counts the rest in ``dropped`` — it
    never overflows silently;
  * :func:`telemetry_decode` — the one host sync: state + per-period
    rows -> a :class:`TelemetryRecord` of plain numpy/dataclass fields
    with JSON-lines and Prometheus-text exporters
    (:func:`to_jsonl`, :func:`to_prometheus`);
  * :func:`stage` / :func:`timed_stages` / :func:`trace_capture` —
    ``jax.named_scope`` + ``jax.profiler.TraceAnnotation`` spans around
    the report-period stages and the reusable best/median/spread timer
    behind ``benchmarks/fleet.py --profile``.

``simulate_fleet(telemetry=None)`` (the default) never builds any of
this — the traced programs are bit-identical to the prior engine,
pinned by ``tests/test_sim_telemetry.py``.

In-scan delay is recomputed in f32 from the same formula as
``engine.split_metrics`` (profile delay constants + bytes over floored
throughput); it feeds histograms and running stats, not the f64
``FleetResult.delay_s`` arrays, so the histogram-grade precision is
deliberate.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from typing import Callable, Mapping, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32
I32 = jnp.int32

# mirrors engine.TP_FLOOR_BPS / channel.throughput.PRB_FLOOR_MBPS (kept
# literal here: telemetry sits below the engine in the import graph)
_TP_FLOOR_BPS = 1.0
_PRB_FLOOR_MBPS = 0.01

# ------------------------------------------------------------- event kinds
EV_ADMIT = 1  # arg = session id, val = admission latency (periods queued)
EV_DEPART = 2  # arg = departures this period
EV_HANDOVER = 3  # arg = UEs whose cell index changed this period
EV_DRIFT_TRIGGER = 4  # arg = trigger ordinal, val = period RMSE (Mbps)
EV_DRIFT_RECOVER = 5  # val = period RMSE back under the threshold
EV_BURST_START = 6  # arg = scheduled AdamW steps
EV_BURST_END = 7  # arg = steps run, val = mean minibatch loss
EV_WEIGHT_SWAP = 8  # serving-mesh weight refresh after a burst

EVENT_NAMES = {EV_ADMIT: "admit", EV_DEPART: "depart",
               EV_HANDOVER: "handover", EV_DRIFT_TRIGGER: "drift_trigger",
               EV_DRIFT_RECOVER: "drift_recover",
               EV_BURST_START: "burst_start", EV_BURST_END: "burst_end",
               EV_WEIGHT_SWAP: "weight_swap"}

# ------------------------------------------------------------ stat channels
STAT_ERR = 0  # |est - true| full-grant estimate error (Mbps), per slot
STAT_DELAY = 1  # E2E delay at the deployed split (s), per slot
STAT_SHARE = 2  # granted PRB share, per slot
STAT_EST = 3  # estimate fed to the controller (Mbps), per slot
STAT_TRUE = 4  # measured throughput (Mbps), per slot
STAT_OCC = 5  # active slots, one sample per period
N_STATS = 6
STAT_NAMES = ("err_abs_mbps", "delay_s", "prb_share", "est_tp_mbps",
              "true_tp_mbps", "occupancy")


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Static telemetry knobs (frozen + hashable: keys the program caches).

    Histogram ranges clip into the last bucket, so out-of-range samples
    are counted, never lost. ``split_bins`` buckets are *split index + 1*
    — bucket 0 holds ``NO_SPLIT`` decisions — and must cover the
    profile's layer count + 1. ``trace_dir`` opts into a
    ``jax.profiler.trace`` capture around the run (default off: the
    profiler is for humans, the metric plane is always-on)."""

    split_bins: int = 48  # split index + 1 (bucket 0 = NO_SPLIT)
    err_bins: int = 32
    err_max_mbps: float = 40.0  # ~ the PSO TP_CLIP sweep range
    delay_bins: int = 32
    delay_max_s: float = 2.0
    share_bins: int = 16  # PRB share in [0, 1]
    occ_bins: int = 16  # occupancy fraction in [0, 1]
    events_capacity: int = 4096
    trace_dir: Optional[str] = None

    def __post_init__(self):
        for f in ("split_bins", "err_bins", "delay_bins", "share_bins",
                  "occ_bins", "events_capacity"):
            if int(getattr(self, f)) <= 0:
                raise ValueError(f"{f} must be positive: {getattr(self, f)}")
        if self.err_max_mbps <= 0 or self.delay_max_s <= 0:
            raise ValueError("histogram ranges must be positive")


class EventRing(NamedTuple):
    """Fixed-capacity device event log (first-``C`` kept, rest counted).

    Unlike the replay ring, old events are never overwritten: a debugging
    timeline must keep its *head* (the drift trigger matters more than
    the 4000th admission after it). ``dropped`` counts what didn't fit —
    the overflow is loud, never silent."""

    kind: jax.Array  # (C,) i32 EV_* codes
    period: jax.Array  # (C,) i32 report period of the event
    arg: jax.Array  # (C,) i32 integer payload (sid / count / steps)
    val: jax.Array  # (C,) f32 float payload (latency / rmse / loss)
    count: jax.Array  # i32 scalar — events stored
    dropped: jax.Array  # i32 scalar — events that found the ring full


class TelemetryState(NamedTuple):
    """The device-resident metric plane carried through the scan."""

    periods: jax.Array  # i32 — report periods observed
    active_steps: jax.Array  # i32 — live (slot, period) samples
    admitted: jax.Array  # i32 — admissions recorded
    departed: jax.Array  # i32 — departures recorded
    handovers: jax.Array  # i32 — cell-index changes recorded
    split_hist: jax.Array  # (split_bins,) i32
    err_hist: jax.Array  # (err_bins,) i32
    delay_hist: jax.Array  # (delay_bins,) i32
    share_hist: jax.Array  # (share_bins,) i32
    occ_hist: jax.Array  # (occ_bins,) i32 — one sample per period
    sums: jax.Array  # (N_STATS,) f32 running sums
    mins: jax.Array  # (N_STATS,) f32 running minima (+inf when empty)
    maxs: jax.Array  # (N_STATS,) f32 running maxima (-inf when empty)
    events: EventRing


class TelemetryRow(NamedTuple):
    """One period's time-series row (stacked by the scan into (T,) ys)."""

    n_active: jax.Array  # i32
    err_sq_sum: jax.Array  # f32 — sum over active slots of (est - true)^2
    delay_sum: jax.Array  # f32 — sum over active slots of delay_s
    admitted: jax.Array  # i32
    departed: jax.Array  # i32


def ring_init(capacity: int) -> EventRing:
    c = int(capacity)
    return EventRing(kind=jnp.zeros((c,), I32), period=jnp.zeros((c,), I32),
                     arg=jnp.zeros((c,), I32), val=jnp.zeros((c,), F32),
                     count=jnp.zeros((), I32), dropped=jnp.zeros((), I32))


def telemetry_init(cfg: TelemetryConfig) -> TelemetryState:
    """An empty metric plane for one run (all leaves device arrays)."""
    zero = jnp.zeros((), I32)
    return TelemetryState(
        periods=zero, active_steps=zero, admitted=zero, departed=zero,
        handovers=zero,
        split_hist=jnp.zeros((cfg.split_bins,), I32),
        err_hist=jnp.zeros((cfg.err_bins,), I32),
        delay_hist=jnp.zeros((cfg.delay_bins,), I32),
        share_hist=jnp.zeros((cfg.share_bins,), I32),
        occ_hist=jnp.zeros((cfg.occ_bins,), I32),
        sums=jnp.zeros((N_STATS,), F32),
        mins=jnp.full((N_STATS,), jnp.inf, F32),
        maxs=jnp.full((N_STATS,), -jnp.inf, F32),
        events=ring_init(cfg.events_capacity))


def ring_push(ring: EventRing, kind, period, arg, val, valid) -> EventRing:
    """Append up to K events (the valid lanes) to the log, in lane order.

    All args are (K,) arrays (scalars broadcast by the caller). The write
    is the replay-ring cumsum-packed scatter: each valid lane takes the
    next free index, lanes past capacity and invalid lanes scatter to
    index ``C`` which ``mode="drop"`` discards. Keep-first semantics:
    overflow increments ``dropped`` instead of overwriting."""
    cap = ring.kind.shape[0]
    valid = jnp.asarray(valid, bool)
    v = valid.astype(I32)
    slot = ring.count + jnp.cumsum(v) - v  # index each valid lane takes
    ok = valid & (slot < cap)
    tgt = jnp.where(ok, slot, cap)
    stored = ok.sum(dtype=I32)
    return EventRing(
        kind=ring.kind.at[tgt].set(jnp.asarray(kind, I32), mode="drop"),
        period=ring.period.at[tgt].set(jnp.asarray(period, I32),
                                       mode="drop"),
        arg=ring.arg.at[tgt].set(jnp.asarray(arg, I32), mode="drop"),
        val=ring.val.at[tgt].set(jnp.asarray(val, F32), mode="drop"),
        count=ring.count + stored,
        dropped=ring.dropped + v.sum(dtype=I32) - stored)


def _bucket(x, scale, bins: int):
    """Linear bucket index into [0, bins): clips into the edge buckets."""
    return jnp.clip((x * scale).astype(I32), 0, bins - 1)


def _masked_hist(hist, bucket, active):
    """Add 1 per active row to its bucket via a one-hot compare-reduce
    (the ``kernels/segsum`` idiom: bins x S comparisons vectorize where an
    XLA CPU scatter serializes, ~4x faster at S=1024). Inactive rows match
    no bucket — histogram totals therefore equal the active-sample count
    exactly."""
    bins = hist.shape[0]
    oh = (bucket[None, :] == jnp.arange(bins, dtype=bucket.dtype)[:, None])
    return hist + (oh & active[None, :]).sum(axis=1, dtype=hist.dtype)


def telemetry_step(cfg: TelemetryConfig, ts: TelemetryState, *, period,
                   split, est_tp, true_tp, share, active, dconst, dbytes,
                   eff_tp=None, admit_sid=None, admit_lat=None,
                   n_depart=None, n_handover=None
                   ) -> tuple[TelemetryState, TelemetryRow]:
    """Fold one report period into the metric plane (pure jnp, scan-safe).

    ``split``/``est_tp``/``true_tp``/``share``/``active``: (S,) per-slot
    arrays as the engine carries them (``split`` may be ``NO_SPLIT``;
    inactive rows contribute to nothing). ``dconst``/``dbytes``: the
    (L,) per-split delay constants (``d_ue + d_ser``) and boundary bytes
    of the run's profile. ``eff_tp`` is the PRB-scaled served throughput
    driving the delay metric (defaults to ``true_tp`` on uncontended
    paths). Event inputs are optional: ``admit_lat`` lanes with latency
    >= 0 log EV_ADMIT events (``admit_sid`` carries the session ids),
    positive ``n_depart``/``n_handover`` log one aggregate event each.
    """
    period = jnp.asarray(period, I32)
    active = jnp.asarray(active, bool)
    actf = active.astype(F32)
    n_act = active.sum(dtype=I32)
    est = jnp.asarray(est_tp, F32)
    true = jnp.asarray(true_tp, F32)
    share = jnp.asarray(share, F32)
    eff = true if eff_tp is None else jnp.asarray(eff_tp, F32)

    err = jnp.abs(est - true)
    nl = dconst.shape[0]
    li = jnp.clip(jnp.asarray(split, I32), 0, nl - 1)
    delay = dconst[li] + dbytes[li] * 8.0 / jnp.maximum(eff * 1e6,
                                                        _TP_FLOOR_BPS)

    # histograms (masked: totals == active samples)
    split_b = jnp.clip(jnp.asarray(split, I32) + 1, 0, cfg.split_bins - 1)
    err_b = _bucket(err, cfg.err_bins / cfg.err_max_mbps, cfg.err_bins)
    delay_b = _bucket(delay, cfg.delay_bins / cfg.delay_max_s,
                      cfg.delay_bins)
    share_b = _bucket(share, float(cfg.share_bins), cfg.share_bins)
    occ_frac = n_act.astype(F32) / active.shape[0]
    occ_b = _bucket(occ_frac[None], float(cfg.occ_bins), cfg.occ_bins)

    # running sum/min/max per stat channel, inactive rows neutralized
    samples = jnp.stack([err, delay, share, est, true])  # (5, S)
    sums5 = (samples * actf).sum(axis=1)
    mins5 = jnp.where(active, samples, jnp.inf).min(axis=1)
    maxs5 = jnp.where(active, samples, -jnp.inf).max(axis=1)
    occf = n_act.astype(F32)
    sums = ts.sums + jnp.concatenate([sums5, occf[None]])
    mins = jnp.minimum(ts.mins, jnp.concatenate([mins5, occf[None]]))
    maxs = jnp.maximum(ts.maxs, jnp.concatenate([maxs5, occf[None]]))

    events = ts.events
    admitted = jnp.zeros((), I32)
    if admit_lat is not None:
        lat = jnp.asarray(admit_lat, I32)
        ok = lat >= 0
        admitted = ok.sum(dtype=I32)
        sid = (jnp.zeros_like(lat) if admit_sid is None
               else jnp.asarray(admit_sid, I32))
        events = ring_push(events, jnp.full_like(lat, EV_ADMIT),
                           jnp.full_like(lat, period), sid,
                           lat.astype(F32), ok)
    departed = jnp.zeros((), I32)
    if n_depart is not None:
        departed = jnp.asarray(n_depart, I32)
        events = ring_push(events, jnp.asarray([EV_DEPART], I32),
                           period[None], departed[None],
                           jnp.zeros((1,), F32), (departed > 0)[None])
    handovers = jnp.zeros((), I32)
    if n_handover is not None:
        handovers = jnp.asarray(n_handover, I32)
        events = ring_push(events, jnp.asarray([EV_HANDOVER], I32),
                           period[None], handovers[None],
                           jnp.zeros((1,), F32), (handovers > 0)[None])

    new = TelemetryState(
        periods=ts.periods + 1,
        active_steps=ts.active_steps + n_act,
        admitted=ts.admitted + admitted,
        departed=ts.departed + departed,
        handovers=ts.handovers + handovers,
        split_hist=_masked_hist(ts.split_hist, split_b, active),
        err_hist=_masked_hist(ts.err_hist, err_b, active),
        delay_hist=_masked_hist(ts.delay_hist, delay_b, active),
        share_hist=_masked_hist(ts.share_hist, share_b, active),
        occ_hist=ts.occ_hist.at[occ_b].add(1),
        sums=sums, mins=mins, maxs=maxs, events=events)
    row = TelemetryRow(
        n_active=n_act,
        err_sq_sum=((est - true) ** 2 * actf).sum(),
        delay_sum=(delay * actf).sum(),
        admitted=admitted, departed=departed)
    return new, row


# ------------------------------------------------- host-loop companion
@jax.jit
def _push_one(ts: TelemetryState, kind, period, arg, val) -> TelemetryState:
    ring = ring_push(ts.events, kind[None], period[None], arg[None],
                     val[None], jnp.ones((1,), bool))
    return ts._replace(events=ring)


@functools.lru_cache(maxsize=None)
def _update_program(cfg: TelemetryConfig):
    """One jitted metric update per config for host-driven loops (the
    online paths): compiled once, reused every period at any occupancy."""

    @jax.jit
    def update(ts, period, split, est, true, eff, share, active, dconst,
               dbytes, admit_sid, admit_lat, n_depart):
        return telemetry_step(cfg, ts, period=period, split=split,
                              est_tp=est, true_tp=true, eff_tp=eff,
                              share=share, active=active, dconst=dconst,
                              dbytes=dbytes, admit_sid=admit_sid,
                              admit_lat=admit_lat, n_depart=n_depart)

    return update


class HostTelemetry:
    """The metric plane for host-driven period loops (the online paths).

    Wraps a device :class:`TelemetryState` with per-period jitted metric
    updates, host event pushes and drift-edge tracking, so the four
    online loops share one telemetry idiom. Everything stays on device;
    :meth:`decode` is the single host sync."""

    def __init__(self, cfg: TelemetryConfig,
                 ts: Optional[TelemetryState] = None):
        self.cfg = cfg
        self.ts = telemetry_init(cfg) if ts is None else ts
        self.rows: list = []
        self._in_drift = False

    def update(self, *, period, split, est, true, share, active, dconst,
               dbytes, eff=None, admit_sid=None, admit_lat=None,
               n_depart=0):
        s = np.shape(active)[0]
        if admit_lat is None:
            admit_sid, admit_lat = (jnp.zeros((1,), I32),
                                    -jnp.ones((1,), I32))
        self.ts, row = _update_program(self.cfg)(
            self.ts, jnp.asarray(period, I32), jnp.asarray(split, I32),
            jnp.asarray(est, F32), jnp.asarray(true, F32),
            jnp.asarray(true if eff is None else eff, F32),
            jnp.asarray(share, F32) if np.ndim(share) else
            jnp.full((s,), share, F32),
            jnp.asarray(active, bool), dconst, dbytes,
            jnp.asarray(admit_sid, I32), jnp.asarray(admit_lat, I32),
            jnp.asarray(n_depart, I32))
        self.rows.append(row)

    def event(self, kind: int, period: int, arg: int = 0, val: float = 0.0):
        self.ts = _push_one(self.ts, jnp.asarray(kind, I32),
                            jnp.asarray(period, I32), jnp.asarray(arg, I32),
                            jnp.asarray(val, F32))

    def drift(self, period: int, fired: bool, rmse: float,
              threshold: float, n_triggers: int = 0):
        """Feed the period's monitor outcome; logs trigger/recovery edges
        (recovery = first post-trigger period back under the threshold)."""
        if fired:
            self._in_drift = True
            self.event(EV_DRIFT_TRIGGER, period, arg=n_triggers, val=rmse)
        elif self._in_drift and rmse <= threshold:
            self._in_drift = False
            self.event(EV_DRIFT_RECOVER, period, val=rmse)

    def burst(self, period: int, steps: int, loss: float, swapped: bool):
        self.event(EV_BURST_START, period, arg=steps)
        self.event(EV_BURST_END, period, arg=steps, val=loss)
        if swapped:
            self.event(EV_WEIGHT_SWAP, period)

    def decode(self, rows=None) -> "TelemetryRecord":
        return telemetry_decode(self.cfg, self.ts,
                                rows if rows is not None else self.rows)


# ----------------------------------------------------------- host decode
@dataclasses.dataclass
class TelemetryEvent:
    """One decoded event (host side of the :class:`EventRing`)."""

    kind: str
    period: int
    arg: int
    value: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TelemetryRecord:
    """The decoded metric plane of one run (``FleetResult.telemetry``)."""

    periods: int
    active_steps: int
    admitted: int
    departed: int
    handovers: int
    stats: dict  # name -> {mean, min, max}
    hists: dict  # name -> {edges: [b+1 floats], counts: [b ints]}
    series: dict  # name -> (T,) list (occupancy / rmse / mean_delay_s /
    # admitted / departed); empty when no per-period rows were kept
    events: list  # [TelemetryEvent] in period order
    dropped_events: int

    def event_timeline(self, kinds: Optional[Sequence[str]] = None) -> list:
        """Events filtered to ``kinds`` (default: all), period order."""
        return [e for e in self.events if kinds is None or e.kind in kinds]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["events"] = [e.to_dict() for e in self.events]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TelemetryRecord":
        d = dict(d)
        d["events"] = [TelemetryEvent(**e) for e in d.get("events", [])]
        return cls(**d)


def _edges(lo: float, hi: float, bins: int) -> list:
    return [lo + (hi - lo) * i / bins for i in range(bins + 1)]


def _stack_rows(rows) -> Optional[TelemetryRow]:
    if rows is None:
        return None
    if isinstance(rows, TelemetryRow):  # scan ys: already stacked (T,)
        return rows
    if len(rows) == 0:
        return None
    return TelemetryRow(*(np.asarray(x) for x in zip(*rows)))


def telemetry_decode(cfg: TelemetryConfig, ts: TelemetryState, rows=None
                     ) -> TelemetryRecord:
    """Device state (+ optional per-period rows) -> host record. The one
    host sync of the telemetry plane; everything before it is jnp."""
    periods = int(ts.periods)
    active_steps = int(ts.active_steps)
    sums = np.asarray(ts.sums, float)
    mins = np.asarray(ts.mins, float)
    maxs = np.asarray(ts.maxs, float)
    denom = np.array([max(active_steps, 1)] * (N_STATS - 1)
                     + [max(periods, 1)], float)
    seen = np.array([active_steps] * (N_STATS - 1) + [periods]) > 0
    stats = {name: {"mean": float(sums[i] / denom[i]) if seen[i] else 0.0,
                    "min": float(mins[i]) if seen[i] else 0.0,
                    "max": float(maxs[i]) if seen[i] else 0.0}
             for i, name in enumerate(STAT_NAMES)}
    hists = {
        "split": {"edges": _edges(-1, cfg.split_bins - 1, cfg.split_bins),
                  "counts": np.asarray(ts.split_hist).tolist()},
        "err_mbps": {"edges": _edges(0, cfg.err_max_mbps, cfg.err_bins),
                     "counts": np.asarray(ts.err_hist).tolist()},
        "delay_s": {"edges": _edges(0, cfg.delay_max_s, cfg.delay_bins),
                    "counts": np.asarray(ts.delay_hist).tolist()},
        "share": {"edges": _edges(0, 1, cfg.share_bins),
                  "counts": np.asarray(ts.share_hist).tolist()},
        "occupancy": {"edges": _edges(0, 1, cfg.occ_bins),
                      "counts": np.asarray(ts.occ_hist).tolist()}}
    series: dict = {}
    stacked = _stack_rows(rows)
    if stacked is not None:
        n_act = np.asarray(stacked.n_active, float)
        live = np.maximum(n_act, 1.0)
        series = {
            "occupancy": np.asarray(stacked.n_active).tolist(),
            "rmse_mbps": np.sqrt(
                np.asarray(stacked.err_sq_sum, float) / live).tolist(),
            "mean_delay_s": (np.asarray(stacked.delay_sum, float)
                             / live).tolist(),
            "admitted": np.asarray(stacked.admitted).tolist(),
            "departed": np.asarray(stacked.departed).tolist()}
    count = int(ts.events.count)
    kinds = np.asarray(ts.events.kind)[:count]
    evp = np.asarray(ts.events.period)[:count]
    args = np.asarray(ts.events.arg)[:count]
    vals = np.asarray(ts.events.val, float)[:count]
    order = np.argsort(evp, kind="stable")
    events = [TelemetryEvent(kind=EVENT_NAMES.get(int(kinds[i]),
                                                  str(int(kinds[i]))),
                             period=int(evp[i]), arg=int(args[i]),
                             value=float(vals[i])) for i in order]
    return TelemetryRecord(
        periods=periods, active_steps=active_steps,
        admitted=int(ts.admitted), departed=int(ts.departed),
        handovers=int(ts.handovers), stats=stats, hists=hists,
        series=series, events=events, dropped_events=int(ts.events.dropped))


# -------------------------------------------------------------- exporters
def to_jsonl(record: TelemetryRecord, path: str,
             period_s: float = 0.1) -> None:
    """JSON-lines time series: one object per report period (skipped when
    the record kept no per-period rows), then one ``summary`` line."""
    import json
    import os
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    names = list(record.series)
    with open(path, "w") as f:
        for t in range(len(record.series.get("occupancy", []))):
            row = {"period": t, "t_s": t * period_s}
            row.update({k: record.series[k][t] for k in names})
            f.write(json.dumps(row) + "\n")
        summary = record.to_dict()
        summary.pop("series", None)
        f.write(json.dumps({"summary": summary}) + "\n")


def to_prometheus(record: TelemetryRecord, prefix: str = "fleet") -> str:
    """The record as Prometheus text exposition (counters, stat gauges,
    cumulative ``_bucket`` histograms) — what a scrape endpoint serving
    one run's telemetry would return."""
    lines = []

    def counter(name, value, help_):
        lines.append(f"# HELP {prefix}_{name} {help_}")
        lines.append(f"# TYPE {prefix}_{name} counter")
        lines.append(f"{prefix}_{name} {value}")

    counter("periods_total", record.periods, "report periods observed")
    counter("active_slot_steps_total", record.active_steps,
            "live (slot, period) samples")
    counter("admitted_total", record.admitted, "sessions admitted")
    counter("departed_total", record.departed, "sessions departed")
    counter("handovers_total", record.handovers, "cell handovers")
    counter("events_dropped_total", record.dropped_events,
            "events that found the ring full")
    for name, st in record.stats.items():
        base = f"{prefix}_{name}"
        lines.append(f"# HELP {base} running {name} statistics")
        lines.append(f"# TYPE {base} gauge")
        for agg in ("mean", "min", "max"):
            lines.append(f'{base}{{agg="{agg}"}} {st[agg]}')
    for hname, h in record.hists.items():
        base = f"{prefix}_{hname}"
        lines.append(f"# HELP {base} {hname} histogram")
        lines.append(f"# TYPE {base} histogram")
        cum = 0
        for edge, c in zip(h["edges"][1:], h["counts"]):
            cum += c
            lines.append(f'{base}_bucket{{le="{edge:g}"}} {cum}')
        lines.append(f'{base}_bucket{{le="+Inf"}} {cum}')
        lines.append(f"{base}_count {cum}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------- stage tracing
@contextlib.contextmanager
def stage(name: str):
    """A named report-period stage: ``jax.named_scope`` labels the traced
    ops (visible in HLO / profiler op names) and
    ``jax.profiler.TraceAnnotation`` spans the host wall time (visible on
    the profiler timeline under a ``trace_capture``). Numerically a
    no-op."""
    with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
        yield


@contextlib.contextmanager
def trace_capture(log_dir: Optional[str]):
    """Opt-in ``jax.profiler.trace`` capture: with a dir, the enclosed run
    lands as a TensorBoard-loadable profile; with None, a no-op."""
    if log_dir is None:
        yield
    else:
        with jax.profiler.trace(log_dir):
            yield


class StageStat(NamedTuple):
    """Wall-time summary of repeated stage runs, in seconds."""

    best: float
    median: float
    spread: float  # max - min over the reps

    def ms(self) -> dict:
        return {"best_ms": self.best * 1e3, "median_ms": self.median * 1e3,
                "spread_ms": self.spread * 1e3}


def timed(fn: Callable[[], object], reps: int = 2) -> StageStat:
    """Time ``fn()`` ``reps`` times (call once beforehand to warm jit
    caches): best filters scheduler noise, median is the honest center,
    spread flags unstable hosts."""
    times = []
    for _ in range(max(1, int(reps))):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return StageStat(best=min(times), median=float(np.median(times)),
                     spread=max(times) - min(times))


def timed_stages(stages: Mapping[str, Callable[[], object]],
                 reps: int = 2) -> dict:
    """name -> :class:`StageStat` for a dict of stage thunks, each run
    under its :func:`stage` span (so a concurrent ``trace_capture`` sees
    the same labels the wall-clock table reports)."""
    out = {}
    for name, fn in stages.items():
        with stage(name):
            fn()  # warm (and span the compile, if any, under the label)
        with stage(name):
            out[name] = timed(fn, reps)
    return out
