"""Closed-loop online estimator adaptation: drift-triggered continual
learning inside the fleet engine.

The paper's estimator is trained once offline and served frozen; under
the scenario/handover drift the fleet engine simulates, its error grows
and split decisions degrade. This module closes the missing half of the
serving loop — estimate -> act -> observe -> learn — at fleet scale,
using labels the fleet already produces for free (the measured per-period
throughput each report period emits):

  * :class:`ReplayBuffer` — a device-resident, fixed-capacity pure-jnp
    ring buffer of (kpms, iq, alloc -> measured tp) samples, row axis
    carrying the logical ``batch`` axis so under a ``ServingMesh`` the
    buffer itself is sharded over the mesh's data axis;
  * :func:`drift_step` — an EWMA monitor of the per-period estimator RMSE
    with a trigger threshold calibrated on the first healthy periods,
    plus patience (consecutive above-threshold periods to fire) and
    cooldown hysteresis so transient noise never triggers retraining;
  * :func:`online_estimate_fleet` — the per-report-period loop: predict
    with the current weights (the same cached ``sim.serving`` program an
    AF pod runs), ingest the period's samples, update the monitor, and on
    a trigger run K jitted AdamW steps on buffer minibatches — the step
    comes from ``estimator.train.make_indexed_step``, shared with the
    offline loop, traced under the serving mesh (data-sharded batch,
    replicated params, psum'd grads) — then swap the refreshed weights
    back into the serving cache (``serving.replicate_params``: a cache
    hit, no retrace) and checkpoint them via
    ``checkpoint.CheckpointManager``.

``simulate_fleet(online=None)`` never enters this module: the engine's
default path is bit-identical to the PR 4 program (pinned by
``tests/test_sim_online.py``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel import kpm as kpmmod
from repro.checkpoint import CheckpointManager
from repro.core.pso import TP_CLIP_MBPS
from repro.dist import sharding as sh
from repro.estimator.model import EstimatorConfig
from repro.estimator.ssm import (SSMConfig, episode_features,
                                 reduce_forecasts, ssm_state_init, ssm_step)
from repro.estimator.train import (fwd, make_indexed_step,
                                   make_indexed_step_ssm)
from repro.kernels.quant.ref import quantize_ref
from repro.optim import AdamW
from repro.sim import telemetry as telmod
from repro.sim.serving import (STATE_AXES, ServingMesh, replicate_params,
                               serving_program, ssm_serving_program)

F32 = jnp.float32
I32 = jnp.int32

RING_QUANT_MODES = (None, "int8")


# --------------------------------------------------------------- buffer
class ReplayBuffer(NamedTuple):
    """Fixed-capacity ring of fleet samples, all leaves device-resident.

    Row 0..capacity-1 is the ring; ``head`` is the next write slot and
    ``seen`` the total rows ever ingested (``min(seen, capacity)`` rows
    are valid). The row axis is the logical ``batch`` axis: under a
    serving mesh the buffer shards over the data axis like any fleet
    batch."""

    kpms: jax.Array  # (C, WINDOW, 15) normalized KPM windows
    iq: jax.Array  # (C, 2, n_sc, 14) spectrograms
    alloc: jax.Array  # (C,) PRB allocation ratios
    tp: jax.Array  # (C,) measured throughput labels (Mbps)
    head: jax.Array  # i32 scalar — next write slot
    seen: jax.Array  # i32 scalar — total rows ever ingested

    @property
    def capacity(self) -> int:
        return self.tp.shape[0]


class ReplayBufferQ(NamedTuple):
    """The int8 ring (``OnlineConfig.ring_quant="int8"``): same contract
    as :class:`ReplayBuffer` but the two big sample tensors are stored as
    rowwise-quantized int8 plus one f32 scale per sample — the
    ``kernels/quant`` formula applied inside the ingest scatter, ~4x less
    replay memory. Minibatches are dequantized on the trainer's
    in-program gather (``estimator.train.make_indexed_step``)."""

    kpms_q: jax.Array  # (C, WINDOW, 15) int8
    kpms_s: jax.Array  # (C, 1) f32 rowwise scales
    iq_q: jax.Array  # (C, 2, n_sc, 14) int8
    iq_s: jax.Array  # (C, 1) f32 rowwise scales
    alloc: jax.Array  # (C,) PRB allocation ratios
    tp: jax.Array  # (C,) measured throughput labels (Mbps)
    head: jax.Array  # i32 scalar — next write slot
    seen: jax.Array  # i32 scalar — total rows ever ingested

    @property
    def capacity(self) -> int:
        return self.tp.shape[0]


class ReplayBufferSSM(NamedTuple):
    """The recurrent estimator's ring: each row is one report event —
    the per-UE SSD state *as it was* before the report, the report's
    features, and the measured-throughput label. Replaying a row re-runs
    exactly one recurrence step from the stored state (truncated BPTT,
    length 1 — ``estimator.train.ssm_step_loss``), so replay cost never
    depends on how much history the live states have absorbed. No int8
    variant: quantizing stored states would perturb every replayed
    gradient (``ring_quant`` is refused for SSM configs)."""

    state: jax.Array  # (C, G, nh//G, hd, N) pre-report recurrent states
    feats: jax.Array  # (C, F) report features
    tp: jax.Array  # (C,) measured throughput labels (Mbps)
    head: jax.Array  # i32 scalar — next write slot
    seen: jax.Array  # i32 scalar — total rows ever ingested

    @property
    def capacity(self) -> int:
        return self.tp.shape[0]


def _rowq(x):
    """Per-sample quantization of an (n, ...) batch: the ``kernels/quant``
    rowwise formula over each sample's flattened features."""
    q, s = quantize_ref(x.reshape(x.shape[0], -1))
    return q.reshape(x.shape), s


def buffer_init(capacity: int, e: EstimatorConfig,
                serving: Optional[ServingMesh] = None,
                quant: Optional[str] = None):
    """An empty ring for ``capacity`` rows of this estimator's shapes.

    With ``serving`` the sample arrays are committed row-sharded over the
    mesh's data axis (``dist.sharding.put`` under the ``batch`` rule); on
    a single device / no mesh they are plain device arrays.
    ``quant="int8"`` builds the quantized ring (:class:`ReplayBufferQ`).
    An :class:`~repro.estimator.ssm.SSMConfig` builds the recurrent ring
    (:class:`ReplayBufferSSM`; ``quant`` must then be None)."""
    if quant not in RING_QUANT_MODES:
        raise ValueError(
            f"ring_quant must be one of {RING_QUANT_MODES}: {quant!r}")
    if isinstance(e, SSMConfig):
        if quant is not None:
            raise ValueError(
                "ring_quant applies to the windowed estimator's ring; the "
                "recurrent ring stores states exactly (fp32)")
        z = {"state": jnp.zeros((capacity,) + e.state_shape(), F32),
             "feats": jnp.zeros((capacity, e.n_feats), F32),
             "tp": jnp.zeros((capacity,), F32)}
        cls = ReplayBufferSSM
    elif quant == "int8":
        z = {"kpms_q": jnp.zeros((capacity, e.window, e.n_kpms), jnp.int8),
             "kpms_s": jnp.ones((capacity, 1), F32),
             "iq_q": jnp.zeros((capacity, 2, e.n_sc, e.n_sym), jnp.int8),
             "iq_s": jnp.ones((capacity, 1), F32),
             "alloc": jnp.zeros((capacity,), F32),
             "tp": jnp.zeros((capacity,), F32)}
        cls = ReplayBufferQ
    else:
        z = {"kpms": jnp.zeros((capacity, e.window, e.n_kpms), F32),
             "iq": jnp.zeros((capacity, 2, e.n_sc, e.n_sym), F32),
             "alloc": jnp.zeros((capacity,), F32),
             "tp": jnp.zeros((capacity,), F32)}
        cls = ReplayBuffer
    if serving is not None:
        with sh.use_rules(serving.mesh, serving.rule_overrides()):
            z = {k: sh.put(v, ("batch",) + (None,) * (v.ndim - 1))
                 for k, v in z.items()}
    return cls(head=jnp.zeros((), I32), seen=jnp.zeros((), I32), **z)


@functools.partial(jax.jit, donate_argnums=0)
def _ring_scatter(buf: ReplayBuffer, kpms, iq, alloc, tp) -> ReplayBuffer:
    # the buffer is donated: callers always rebind (buf = buffer_add(buf,
    # ...)), so the .at[].set updates run in place instead of copying the
    # whole capacity-sized ring every report period
    cap = buf.tp.shape[0]
    n = tp.shape[0]
    idx = (buf.head + jnp.arange(n, dtype=I32)) % cap
    return ReplayBuffer(
        kpms=buf.kpms.at[idx].set(kpms),
        iq=buf.iq.at[idx].set(iq),
        alloc=buf.alloc.at[idx].set(alloc),
        tp=buf.tp.at[idx].set(tp),
        head=(buf.head + n) % cap,
        seen=buf.seen + n)


@functools.partial(jax.jit, donate_argnums=0)
def _ring_scatter_q(buf: ReplayBufferQ, kpms, iq, alloc,
                    tp) -> ReplayBufferQ:
    # same in-place ring write as _ring_scatter, with the two big tensors
    # rowwise-quantized inside the donated program (no fp32 staging copy)
    cap = buf.tp.shape[0]
    n = tp.shape[0]
    idx = (buf.head + jnp.arange(n, dtype=I32)) % cap
    kq, ks = _rowq(kpms)
    iqq, iqs = _rowq(iq)
    return ReplayBufferQ(
        kpms_q=buf.kpms_q.at[idx].set(kq),
        kpms_s=buf.kpms_s.at[idx].set(ks),
        iq_q=buf.iq_q.at[idx].set(iqq),
        iq_s=buf.iq_s.at[idx].set(iqs),
        alloc=buf.alloc.at[idx].set(alloc),
        tp=buf.tp.at[idx].set(tp),
        head=(buf.head + n) % cap,
        seen=buf.seen + n)


def buffer_add(buf, kpms, iq, alloc, tp):
    """Ring-ingest a batch of N sample rows (oldest rows overwritten).

    N > capacity keeps the newest ``capacity`` rows — the overflow is
    dropped *before* the scatter so its indices stay unique (a scatter
    with duplicate indices has no defined write order)."""
    cap = int(buf.tp.shape[0])
    n = int(np.shape(tp)[0])
    if n > cap:
        kpms, iq, alloc, tp = (x[-cap:] for x in (kpms, iq, alloc, tp))
    scatter = (_ring_scatter_q if isinstance(buf, ReplayBufferQ)
               else _ring_scatter)
    return scatter(buf, jnp.asarray(kpms, F32), jnp.asarray(iq, F32),
                   jnp.asarray(alloc, F32), jnp.asarray(tp, F32))


@functools.partial(jax.jit, donate_argnums=0)
def _ring_scatter_masked(buf: ReplayBuffer, kpms, iq, alloc, tp,
                         mask) -> ReplayBuffer:
    # masked rows are packed to the front of the write (cumsum of the mask
    # gives each valid row its offset from head) and the rest scattered to
    # index ``cap`` which ``mode="drop"`` discards — fixed shapes, so the
    # program never retraces as the live population churns
    cap = buf.tp.shape[0]
    m = mask.astype(I32)
    k = m.sum()
    pos = jnp.cumsum(m) - 1
    idx = jnp.where(mask, (buf.head + pos) % cap, cap)
    return ReplayBuffer(
        kpms=buf.kpms.at[idx].set(kpms, mode="drop"),
        iq=buf.iq.at[idx].set(iq, mode="drop"),
        alloc=buf.alloc.at[idx].set(alloc, mode="drop"),
        tp=buf.tp.at[idx].set(tp, mode="drop"),
        head=(buf.head + k) % cap,
        seen=buf.seen + k)


@functools.partial(jax.jit, donate_argnums=0)
def _ring_scatter_masked_q(buf: ReplayBufferQ, kpms, iq, alloc, tp,
                           mask) -> ReplayBufferQ:
    # _ring_scatter_masked with in-program rowwise quantization; masked
    # rows are quantized too (fixed shapes) but dropped at the scatter
    cap = buf.tp.shape[0]
    m = mask.astype(I32)
    k = m.sum()
    pos = jnp.cumsum(m) - 1
    idx = jnp.where(mask, (buf.head + pos) % cap, cap)
    kq, ks = _rowq(kpms)
    iqq, iqs = _rowq(iq)
    return ReplayBufferQ(
        kpms_q=buf.kpms_q.at[idx].set(kq, mode="drop"),
        kpms_s=buf.kpms_s.at[idx].set(ks, mode="drop"),
        iq_q=buf.iq_q.at[idx].set(iqq, mode="drop"),
        iq_s=buf.iq_s.at[idx].set(iqs, mode="drop"),
        alloc=buf.alloc.at[idx].set(alloc, mode="drop"),
        tp=buf.tp.at[idx].set(tp, mode="drop"),
        head=(buf.head + k) % cap,
        seen=buf.seen + k)


def buffer_add_masked(buf, kpms, iq, alloc, tp, mask):
    """Ring-ingest only the rows where ``mask`` is True (the slot-pool
    path: a churning fleet must not train on empty slots' zero samples).

    The write stays a fixed-shape scatter — invalid rows are dropped at
    the scatter, not gathered on the host — so one compiled program
    serves every occupancy level. Requires ``len(tp) <= capacity`` so the
    in-bounds indices stay unique (a slot pool's capacity is bounded by
    its replay ring's)."""
    cap = int(buf.tp.shape[0])
    n = int(np.shape(tp)[0])
    if n > cap:
        raise ValueError(
            f"masked ingest of {n} slots exceeds ring capacity {cap}; "
            "size OnlineConfig.capacity >= the slot-pool capacity")
    scatter = (_ring_scatter_masked_q if isinstance(buf, ReplayBufferQ)
               else _ring_scatter_masked)
    return scatter(buf, jnp.asarray(kpms, F32),
                   jnp.asarray(iq, F32),
                   jnp.asarray(alloc, F32),
                   jnp.asarray(tp, F32),
                   jnp.asarray(mask, bool))


@functools.partial(jax.jit, donate_argnums=0)
def _ring_scatter_ssm(buf: ReplayBufferSSM, state, feats,
                      tp) -> ReplayBufferSSM:
    # the recurrent ring's in-place write (see _ring_scatter)
    cap = buf.tp.shape[0]
    n = tp.shape[0]
    idx = (buf.head + jnp.arange(n, dtype=I32)) % cap
    return ReplayBufferSSM(
        state=buf.state.at[idx].set(state),
        feats=buf.feats.at[idx].set(feats),
        tp=buf.tp.at[idx].set(tp),
        head=(buf.head + n) % cap,
        seen=buf.seen + n)


@functools.partial(jax.jit, donate_argnums=0)
def _ring_scatter_masked_ssm(buf: ReplayBufferSSM, state, feats, tp,
                             mask) -> ReplayBufferSSM:
    # _ring_scatter_masked for the recurrent ring (slot-pool ingest:
    # cumsum-packed valid rows, invalid rows dropped at index ``cap``)
    cap = buf.tp.shape[0]
    m = mask.astype(I32)
    k = m.sum()
    pos = jnp.cumsum(m) - 1
    idx = jnp.where(mask, (buf.head + pos) % cap, cap)
    return ReplayBufferSSM(
        state=buf.state.at[idx].set(state, mode="drop"),
        feats=buf.feats.at[idx].set(feats, mode="drop"),
        tp=buf.tp.at[idx].set(tp, mode="drop"),
        head=(buf.head + k) % cap,
        seen=buf.seen + k)


def buffer_add_ssm(buf: ReplayBufferSSM, state, feats, tp,
                   mask=None) -> ReplayBufferSSM:
    """Ring-ingest N report events (pre-report state, features, label).

    ``mask`` selects live rows (the slot-pool path) through the packed
    fixed-shape scatter; without one, overflow keeps the newest
    ``capacity`` rows exactly like :func:`buffer_add`."""
    cap = int(buf.tp.shape[0])
    n = int(np.shape(tp)[0])
    if mask is not None:
        if n > cap:
            raise ValueError(
                f"masked ingest of {n} slots exceeds ring capacity {cap}; "
                "size OnlineConfig.capacity >= the slot-pool capacity")
        return _ring_scatter_masked_ssm(
            buf, jnp.asarray(state, F32), jnp.asarray(feats, F32),
            jnp.asarray(tp, F32), jnp.asarray(mask, bool))
    if n > cap:
        state, feats, tp = (x[-cap:] for x in (state, feats, tp))
    return _ring_scatter_ssm(buf, jnp.asarray(state, F32),
                             jnp.asarray(feats, F32), jnp.asarray(tp, F32))


def buffer_count(buf) -> int:
    """Valid rows in the ring (saturates at capacity)."""
    return int(min(int(buf.seen), buf.capacity))


def buffer_data(buf) -> dict:
    """The buffer as the dict ``make_indexed_step`` consumes.

    On the int8 ring the two big fields come out as ``(q, scales)``
    tuples; the trainer's in-program gather dequantizes exactly the
    minibatch rows it selects, never the whole ring."""
    if isinstance(buf, ReplayBufferQ):
        return {"kpms": (buf.kpms_q, buf.kpms_s),
                "iq": (buf.iq_q, buf.iq_s), "alloc": buf.alloc,
                "tp": buf.tp}
    if isinstance(buf, ReplayBufferSSM):
        return {"state": buf.state, "feats": buf.feats, "tp": buf.tp}
    return {"kpms": buf.kpms, "iq": buf.iq, "alloc": buf.alloc,
            "tp": buf.tp}


# ---------------------------------------------------------- drift monitor
@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """EWMA drift monitor knobs (all units are Mbps of estimator RMSE)."""

    alpha: float = 0.25  # EWMA weight of the newest per-period RMSE
    calibrate_periods: int = 5  # healthy periods that set the baseline
    ratio: float = 1.5  # trigger level = ratio * calibrated baseline
    threshold_mbps: Optional[float] = None  # absolute override of ratio
    patience: int = 2  # consecutive above-threshold periods to fire
    cooldown: int = 3  # periods after a trigger before re-arming


@dataclasses.dataclass(frozen=True)
class DriftState:
    """Immutable monitor state; advance with :func:`drift_step`."""

    rmse_ewma: float = 0.0
    has_ewma: bool = False
    baseline: float = 0.0  # running mean RMSE of the calibration periods
    seen: int = 0  # periods consumed
    above: int = 0  # consecutive periods above threshold
    cooldown_left: int = 0
    n_triggers: int = 0


def drift_init() -> DriftState:
    return DriftState()


def drift_threshold(cfg: DriftConfig, state: DriftState) -> float:
    """The trigger level in Mbps: absolute if configured, else the
    calibrated ``ratio * baseline``."""
    if cfg.threshold_mbps is not None:
        return float(cfg.threshold_mbps)
    return cfg.ratio * max(state.baseline, 1e-6)


def drift_step(cfg: DriftConfig, state: DriftState, rmse_mbps: float,
               armed: bool = True) -> tuple[DriftState, bool]:
    """Feed one report period's estimator RMSE; returns (state, fired).

    The first ``calibrate_periods`` periods only calibrate the baseline
    (never fire). After that the EWMA must sit above the threshold for
    ``patience`` consecutive periods to fire — one noisy period is not
    drift — and a firing starts a ``cooldown`` during which the monitor is
    disarmed (the freshly adapted model needs periods to show its RMSE).

    ``armed=False`` means the caller cannot act on a trigger right now
    (the online loop passes this while the replay buffer is below
    ``min_fill``): the streak still builds but *holds* at ``patience``
    instead of firing — no cooldown is started and no trigger is consumed
    — so the first armed period with a held streak fires immediately."""
    rmse = float(rmse_mbps)
    a = cfg.alpha
    ewma = rmse if not state.has_ewma else a * rmse + (1 - a) * state.rmse_ewma
    seen = state.seen + 1
    if seen <= cfg.calibrate_periods:
        baseline = state.baseline + (rmse - state.baseline) / seen
        return dataclasses.replace(state, rmse_ewma=ewma, has_ewma=True,
                                   baseline=baseline, seen=seen), False
    if state.cooldown_left > 0:
        return dataclasses.replace(state, rmse_ewma=ewma, seen=seen,
                                   above=0,
                                   cooldown_left=state.cooldown_left - 1
                                   ), False
    above = state.above + 1 if ewma > drift_threshold(cfg, state) else 0
    if above >= cfg.patience:
        if not armed:  # hold the streak, don't consume the trigger
            return dataclasses.replace(state, rmse_ewma=ewma, seen=seen,
                                       above=cfg.patience), False
        return dataclasses.replace(state, rmse_ewma=ewma, seen=seen, above=0,
                                   cooldown_left=cfg.cooldown,
                                   n_triggers=state.n_triggers + 1), True
    return dataclasses.replace(state, rmse_ewma=ewma, seen=seen,
                               above=above), False


# --------------------------------------------------------- online trainer
@dataclasses.dataclass(frozen=True)
class OnlineConfig:
    """Knobs of the closed adaptation loop (see docs/online.md)."""

    capacity: int = 4096  # replay-buffer rows
    batch: int = 256  # minibatch rows per adaptation step
    steps: int = 20  # K jitted AdamW steps per trigger
    lr: float = 1e-3
    weight_decay: float = 1e-4
    clip_norm: float = 1.0
    min_fill: int = 256  # don't adapt before this many buffered rows
    seed: int = 0  # minibatch sampling + dropout keys
    ring_quant: Optional[str] = None  # "int8" stores replay samples
    # rowwise-quantized (~4x less ring memory; dequantized on the
    # trainer's minibatch gather). None keeps the exact fp32 ring.
    drift: DriftConfig = DriftConfig()
    ckpt_dir: Optional[str] = None  # CheckpointManager dir for adapted
    # weights; None disables checkpointing
    ckpt_keep: int = 3


@dataclasses.dataclass
class OnlineStats:
    """Host-side trace of one online episode (``FleetResult.online``)."""

    rmse: np.ndarray  # (T,) per-period estimator RMSE vs measured tp
    adapted: np.ndarray  # (T,) bool — an adaptation burst ran after t
    n_adaptations: int
    train_steps: int  # total jitted steps across all bursts
    train_loss: list  # mean minibatch loss per burst
    buffer_fill: int  # valid rows at episode end
    threshold_mbps: float  # the trigger level in effect at episode end
    params: object  # final (possibly adapted) estimator params
    ckpt_steps: list  # CheckpointManager steps written (empty without dir)


@functools.lru_cache(maxsize=None)
def online_step_program(ecfg: EstimatorConfig, opt: AdamW,
                        serving: Optional[ServingMesh]):
    """One compiled adaptation step per (estimator, optimizer, deployment)
    — the shared ``make_indexed_step`` factory, traced under the serving
    mesh when one is given so buffer minibatches shard over the data axis
    and the gradient psum is in the program. An ``SSMConfig`` takes the
    recurrent factory (``make_indexed_step_ssm``) — same calling
    convention, stored-state replay rows."""
    factory = (make_indexed_step_ssm if isinstance(ecfg, SSMConfig)
               else make_indexed_step)
    if serving is None:
        return factory(ecfg, opt)
    return factory(ecfg, opt, mesh=serving.mesh,
                   overrides=serving.rule_overrides())


def online_estimate_fleet(episode, estimator, ocfg: OnlineConfig, *,
                          serving: Optional[ServingMesh] = None,
                          tp_clip=TP_CLIP_MBPS, fused: bool = False,
                          telemetry=None
                          ) -> tuple[np.ndarray, OnlineStats]:
    """(N, T) Mbps estimates + :class:`OnlineStats`: the closed loop.

    Per 0.1 s report period: (1) predict the whole fleet's throughput with
    the *current* weights — the same per-period program ``sim.serving``
    caches, so refreshed weights are a cache hit, never a retrace; (2)
    observe the measured per-period throughput the engine emits
    (``engine.emit_period_samples``) and ring-ingest the (kpms, iq, alloc
    -> tp) rows; (3) feed the period RMSE to the drift monitor; (4) on a
    trigger, run ``ocfg.steps`` jitted AdamW steps on buffer minibatches,
    swap the updated weights into the serving path, and checkpoint them.

    The estimates returned are exactly what the controllers consume
    (clipped into ``tp_clip``); period t+1's estimate already reflects any
    adaptation period t triggered. Split decisions never feed back into
    the labels, so the engine can run its controller scan on the returned
    array afterwards — ``simulate_fleet(online=...)`` does exactly that,
    which keeps online composable with scheduling and fixed baselines.

    ``fused=True`` swaps the WINDOW x host window materialization for
    per-period views of the normalized KPM trace (bit-identical f32
    elements, see ``engine.emit_period_samples``).

    ``telemetry``: an optional ``telemetry.HostTelemetry`` — the loop logs
    drift trigger/recovery, burst start/end and serving weight-swap
    events into its device ring (the *metrics* accumulate later, in the
    engine's controller scan, so nothing is double counted). The returned
    values are unchanged.
    """
    from repro.sim.engine import emit_period_samples

    ecfg, params = estimator
    if isinstance(ecfg, SSMConfig):
        # the recurrent loop: same drift monitor, same AdamW bursts, the
        # ring stores (pre-report state, report, label) events instead of
        # windows; ``fused`` is a no-op (nothing to featurize)
        return _online_estimate_fleet_ssm(episode, ecfg, params, ocfg,
                                          serving=serving, tp_clip=tp_clip,
                                          telemetry=telemetry)
    if episode.iq is None:
        raise ValueError(
            "online adaptation needs IQ spectrograms: generate the episode "
            "with include_iq=True")
    n, t_steps = episode.n_ues, episode.n_steps
    if fused:
        wins = None
        trace = np.ascontiguousarray(
            kpmmod.normalize_kpms(episode.kpms).astype(np.float32))
    else:
        wins = episode.kpm_windows(normalize=True).astype(np.float32)
        trace = None
    opt = AdamW(lr=ocfg.lr, weight_decay=ocfg.weight_decay,
                clip_norm=ocfg.clip_norm)
    opt_state = opt.init(params)
    step_fn = online_step_program(ecfg, opt, serving)
    if serving is not None:
        predict_fn = serving_program(ecfg, serving)
        params = replicate_params(serving, params)
        ctx = sh.use_rules(serving.mesh, serving.rule_overrides())
    else:
        predict_fn = functools.partial(fwd, ecfg)
        ctx = contextlib.nullcontext()
    mgr = (CheckpointManager(ocfg.ckpt_dir, keep=ocfg.ckpt_keep)
           if ocfg.ckpt_dir else None)
    buf = buffer_init(ocfg.capacity, ecfg, serving=serving,
                      quant=ocfg.ring_quant)
    dstate = drift_init()
    rng = np.random.default_rng(ocfg.seed)
    key = jax.random.PRNGKey(ocfg.seed)
    est = np.empty((n, t_steps))
    rmse = np.empty(t_steps)
    adapted = np.zeros(t_steps, bool)
    train_loss: list = []
    ckpt_steps: list = []
    total_steps = 0
    with ctx:
        def place(x, axes):
            return sh.put(jnp.asarray(x, F32), axes)

        alloc_d = place(episode.alloc_ratio, ("batch",))
        for t in range(t_steps):
            s = emit_period_samples(episode, t, wins, trace=trace)
            kpms_t = place(s["kpms"], ("batch", None, None))
            iq_t = place(s["iq"], ("batch", None, None, None))
            with telmod.stage("estimator_fwd"):
                est[:, t] = np.clip(
                    np.asarray(predict_fn(params, kpms_t, iq_t, alloc_d)),
                    tp_clip[0], tp_clip[1])
            tp_t = s["tp"]
            rmse[t] = float(np.sqrt(np.mean((est[:, t] - tp_t) ** 2)))
            buf = buffer_add(buf, kpms_t, iq_t, alloc_d,
                             place(tp_t, ("batch",)))
            fill = buffer_count(buf)
            # below min_fill the monitor holds its streak instead of
            # consuming the trigger: a drift detected before the buffer
            # is ready fires on the first period it can be acted on
            dstate, fired = drift_step(ocfg.drift, dstate, rmse[t],
                                       armed=fill >= ocfg.min_fill)
            if telemetry is not None:
                telemetry.drift(t, bool(fired), rmse[t],
                                drift_threshold(ocfg.drift, dstate),
                                n_triggers=int(dstate.n_triggers))
            if fired:
                data = buffer_data(buf)
                burst = []
                with telmod.stage("online_burst"):
                    for _ in range(ocfg.steps):
                        idx = jnp.asarray(rng.integers(0, fill, ocfg.batch),
                                          I32)
                        key, sub = jax.random.split(key)
                        params, opt_state, loss = step_fn(params, opt_state,
                                                          data, idx, sub)
                        burst.append(float(loss))
                    if serving is not None:
                        # weight refresh: re-commit replicated so the next
                        # period's predict is a compiled-program cache hit
                        with telmod.stage("weight_swap"):
                            params = replicate_params(serving, params)
                total_steps += ocfg.steps
                train_loss.append(float(np.mean(burst)))
                adapted[t] = True
                if telemetry is not None:
                    telemetry.burst(t, ocfg.steps, float(np.mean(burst)),
                                    serving is not None)
                if mgr is not None:
                    mgr.save(dstate.n_triggers, params)  # async
                    ckpt_steps.append(dstate.n_triggers)
    if mgr is not None:
        mgr.wait()
    stats = OnlineStats(rmse=rmse, adapted=adapted,
                        n_adaptations=int(adapted.sum()),
                        train_steps=total_steps, train_loss=train_loss,
                        buffer_fill=buffer_count(buf),
                        threshold_mbps=drift_threshold(ocfg.drift, dstate),
                        params=params, ckpt_steps=ckpt_steps)
    return est, stats


def _online_estimate_fleet_ssm(episode, c: SSMConfig, params,
                               ocfg: OnlineConfig, *,
                               serving: Optional[ServingMesh] = None,
                               tp_clip=TP_CLIP_MBPS, telemetry=None
                               ) -> tuple[np.ndarray, OnlineStats]:
    """The recurrent arm of :func:`online_estimate_fleet`.

    Structurally the same closed loop with two differences born from the
    O(1) ingest. First, predict and observe are *one* program: the
    per-period ``ssm_step`` both advances each UE's recurrent state and
    emits its forecasts — there is no separate featurize stage, and each
    period costs the same whether the fleet has 30 or 30 000 reports of
    history (the first WINDOW - 1 trace columns run through the very same
    step program as label-free warmup). Second, the replay ring stores
    (pre-report state, report features, label) events; an adaptation
    burst replays single recurrence steps from those stored states
    (``estimator.train.ssm_step_loss``). The carried fleet states are
    *not* recomputed after a burst — they were built by older weights,
    and the recurrence's per-period decay forgets them at exp(dt*A);
    re-warming 30 columns per burst would reintroduce the O(WINDOW) cost
    this estimator exists to remove."""
    if episode.kpms is None:
        raise ValueError("the recurrent estimator needs raw KPM reports: "
                         "generate the episode with include_kpms=True")
    if c.include_iq and episode.iq is None:
        raise ValueError("SSMConfig(include_iq=True) needs spectrogram "
                         "snapshots: generate the episode with "
                         "include_iq=True")
    n, t_steps = episode.n_ues, episode.n_steps
    feats = episode_features(episode.kpms, episode.alloc_ratio,
                             episode.iq if c.include_iq else None)
    off = feats.shape[1] - t_steps - 1  # = WINDOW - 1, period 0's column
    opt = AdamW(lr=ocfg.lr, weight_decay=ocfg.weight_decay,
                clip_norm=ocfg.clip_norm)
    opt_state = opt.init(params)
    step_fn = online_step_program(c, opt, serving)
    if serving is not None:
        predict_fn = ssm_serving_program(c, serving)
        params = replicate_params(serving, params)
        ctx = sh.use_rules(serving.mesh, serving.rule_overrides())
    else:
        predict_fn = functools.partial(ssm_step, c)
        ctx = contextlib.nullcontext()
    mgr = (CheckpointManager(ocfg.ckpt_dir, keep=ocfg.ckpt_keep)
           if ocfg.ckpt_dir else None)
    buf = buffer_init(ocfg.capacity, c, serving=serving,
                      quant=ocfg.ring_quant)
    dstate = drift_init()
    rng = np.random.default_rng(ocfg.seed)
    key = jax.random.PRNGKey(ocfg.seed)
    est = np.empty((n, t_steps))
    rmse = np.empty(t_steps)
    adapted = np.zeros(t_steps, bool)
    train_loss: list = []
    ckpt_steps: list = []
    total_steps = 0
    with ctx:
        def place(x, axes):
            return sh.put(jnp.asarray(x, F32), axes)

        state = place(ssm_state_init(c, (n,)), STATE_AXES)
        for col in range(off):  # warmup reports precede the first label
            state, _ = predict_fn(params, state,
                                  place(feats[:, col], ("batch", None)))
        for t in range(t_steps):
            feats_t = place(feats[:, off + t], ("batch", None))
            state_prev = state
            with telmod.stage("estimator_fwd"):
                state, fc = predict_fn(params, state, feats_t)
                fc = np.asarray(fc)
            # the monitor watches the served *current* estimate's error;
            # the controllers consume the policy-reduced forecasts
            cur = np.clip(fc[:, 0], tp_clip[0], tp_clip[1])
            est[:, t] = np.clip(reduce_forecasts(c, fc),
                                tp_clip[0], tp_clip[1])
            tp_t = episode.tp_mbps[:, t].astype(np.float32)
            rmse[t] = float(np.sqrt(np.mean((cur - tp_t) ** 2)))
            buf = buffer_add_ssm(buf, state_prev, feats_t,
                                 place(tp_t, ("batch",)))
            fill = buffer_count(buf)
            dstate, fired = drift_step(ocfg.drift, dstate, rmse[t],
                                       armed=fill >= ocfg.min_fill)
            if telemetry is not None:
                telemetry.drift(t, bool(fired), rmse[t],
                                drift_threshold(ocfg.drift, dstate),
                                n_triggers=int(dstate.n_triggers))
            if fired:
                data = buffer_data(buf)
                burst = []
                with telmod.stage("online_burst"):
                    for _ in range(ocfg.steps):
                        idx = jnp.asarray(rng.integers(0, fill, ocfg.batch),
                                          I32)
                        key, sub = jax.random.split(key)
                        params, opt_state, loss = step_fn(params, opt_state,
                                                          data, idx, sub)
                        burst.append(float(loss))
                    if serving is not None:
                        with telmod.stage("weight_swap"):
                            params = replicate_params(serving, params)
                total_steps += ocfg.steps
                train_loss.append(float(np.mean(burst)))
                adapted[t] = True
                if telemetry is not None:
                    telemetry.burst(t, ocfg.steps, float(np.mean(burst)),
                                    serving is not None)
                if mgr is not None:
                    mgr.save(dstate.n_triggers, params)  # async
                    ckpt_steps.append(dstate.n_triggers)
    if mgr is not None:
        mgr.wait()
    stats = OnlineStats(rmse=rmse, adapted=adapted,
                        n_adaptations=int(adapted.sum()),
                        train_steps=total_steps, train_loss=train_loss,
                        buffer_fill=buffer_count(buf),
                        threshold_mbps=drift_threshold(ocfg.drift, dstate),
                        params=params, ckpt_steps=ckpt_steps)
    return est, stats
