"""Multi-cell fleet layer over ``repro.sim.engine``.

Assigns the fleet's N UEs to C cells, couples the cells through load-
dependent interference, and runs each cell's gNB PRB scheduler inside the
engine's scan:

  * **Attach + handover.** ``attach_ring`` spreads UEs over a ring of
    cells; ``handover_grid`` makes a fraction of them hand over to the
    next cell mid-episode, producing the (N, T + WINDOW) per-period cell
    grid every other piece consumes.
  * **Interference coupling.** A (C, C) matrix (``ring_coupling``) maps
    each cell's aggregate offered load to the interference power (mW) its
    neighbours' UEs see. ``coupled_interference_mw`` turns the cell grid +
    per-UE loads into the (N, T + WINDOW) floor that
    ``gen_episode_batch(extra_int_mw=...)`` power-sums onto every trace —
    so KPMs, IQ and the ground-truth labels all see the coupling.
  * **Scheduling.** ``simulate_cells`` hands the per-period cell grid and
    a ``SchedulerConfig`` to the engine, whose scan co-evolves PRB
    allocation, estimation and splitting (``repro.sim.sched``).

With ``sched=None`` the layer delegates to the engine's default path
untouched — one cell, no coupling, no scheduler reproduces the PR-2
``simulate_fleet`` results bit-for-bit (pinned in tests/test_sim_cells.py).
Everything here is (N,)/(C,)-array math; no Python loops over cells or
UEs touch the hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.channel import throughput as tpmod
from repro.channel.scenarios import WINDOW, EpisodeBatch, gen_episode_batch
from repro.core.controller import ControllerConfig
from repro.core.energy import EDGE_A40X2, UE_VM_2CORE, DeviceProfile
from repro.core.profiles import SplitProfile
from repro.sim.engine import FleetResult, simulate_fleet
from repro.sim.sched import SchedulerConfig


def jain_index(x: np.ndarray, active: np.ndarray | None = None) -> float:
    """Jain fairness of an allocation vector: 1 = perfectly even, 1/n =
    one UE holds everything.

    ``active``: optional bool mask selecting the slots that actually held
    a UE — fairness must be counted over the live population only, or a
    slot pool at 50% occupancy would look unfair purely from its empty
    slots. An all-empty selection is vacuously fair (1.0)."""
    x = np.asarray(x, float)
    if active is not None:
        x = x[np.asarray(active, bool)]
    if x.size == 0:
        return 1.0
    s = float(x.sum())
    return s * s / (len(x) * float((x * x).sum()) + 1e-300)


def ring_coupling(n_cells: int, neighbor_dbm: float = -12.0,
                  decay: float = 0.5) -> np.ndarray:
    """(C, C) inter-cell coupling on a ring: entry [i, j] is the
    interference power (mW at cell i's gNB) a fully-loaded cell j injects.
    Immediate neighbours inject ``10**(neighbor_dbm/10)`` mW, then a
    geometric ``decay`` per extra ring hop; the diagonal is zero (own-cell
    load is contention for PRBs, not interference)."""
    d = np.abs(np.arange(n_cells)[:, None] - np.arange(n_cells)[None])
    d = np.minimum(d, n_cells - d)  # ring distance
    coup = 10 ** (neighbor_dbm / 10) * decay ** (d - 1.0)
    return np.where(d == 0, 0.0, coup)


def attach_ring(n_ues: int, n_cells: int) -> np.ndarray:
    """(N,) initial attach: UEs spread round-robin over the cells."""
    return np.arange(n_ues) % n_cells


def handover_grid(cell0: np.ndarray, n_steps: int, frac: float,
                  rng: np.random.Generator, t_h: int | None = None,
                  n_cells: int | None = None) -> np.ndarray:
    """(N, n_steps) cell grid where ``frac`` of the fleet hands over to the
    next ring cell at step ``t_h``. Pass ``n_steps = T + WINDOW`` so the
    grid aligns with the episode traces; the default ``t_h`` is then the
    middle of the *report* window (past the KPM warm-up prefix), so the
    scheduler scan — which only sees steps >= WINDOW — always observes
    the handover. ``n_cells`` defaults to ``cell0.max() + 1``; pass it
    explicitly when the top ring cell may start with no attached UEs."""
    cell0 = np.asarray(cell0)
    n = len(cell0)
    if n_cells is None:
        n_cells = int(cell0.max()) + 1 if n else 1
    grid = np.repeat(cell0[:, None], n_steps, axis=1)
    n_h = int(round(n * frac))
    if n_h:
        hover = rng.choice(n, n_h, replace=False)
        if t_h is None:
            t_h = (WINDOW + (n_steps - WINDOW) // 2 if n_steps > WINDOW
                   else n_steps // 2)
        grid[hover, t_h:] = (cell0[hover, None] + 1) % n_cells
    return grid


def cell_load(cell_grid: np.ndarray, demand: np.ndarray,
              n_cells: int, *, use_kernel: bool = False) -> np.ndarray:
    """(C, T) aggregate offered load per cell per step: the mean UL load
    ratio of the attached UEs (0 for an empty cell), in [0, 1].

    ``use_kernel`` aggregates through the ``kernels/segsum`` Pallas
    kernel — tiled one-hot reductions over (T, N) batches — instead of
    materializing the (N, T, C) one-hot tensor on the host; allclose to
    the default (pinned by ``tests/test_kernels_fused.py``)."""
    grid = np.asarray(cell_grid)
    if use_kernel:
        from repro.kernels.segsum import segment_reduce
        ids = grid.T.astype(np.int32)  # (T, N)
        dem = np.broadcast_to(np.asarray(demand, np.float32), ids.shape)
        tot = np.asarray(segment_reduce(dem, ids, n_cells, op="sum"))
        cnt = np.asarray(segment_reduce(np.ones_like(dem), ids, n_cells,
                                        op="sum"))
        return np.asarray((tot / np.maximum(cnt, 1)).T, float)  # (C, T)
    onehot = grid[..., None] == np.arange(n_cells)  # (N, T, C)
    tot = (np.asarray(demand, float)[:, None, None] * onehot).sum(axis=0)
    cnt = onehot.sum(axis=0)
    return (tot / np.maximum(cnt, 1)).T  # (C, T)


def coupled_interference_mw(cell_grid: np.ndarray, demand: np.ndarray,
                            coupling: np.ndarray, *,
                            use_kernel: bool = False) -> np.ndarray:
    """(N, T) neighbour-cell interference floor (linear mW) per UE: each
    cell's aggregate load, pushed through the (C, C) coupling matrix, read
    back at every UE through its per-period cell assignment."""
    coupling = np.asarray(coupling, float)
    n_cells = coupling.shape[0]
    load = cell_load(cell_grid, demand, n_cells,
                     use_kernel=use_kernel)  # (C, T)
    at_cell = coupling @ load  # (C, T) extra power at each victim cell
    return at_cell[np.asarray(cell_grid),
                   np.arange(cell_grid.shape[1])[None]]


def build_cells_episode(scenarios, T: int, rng: np.random.Generator,
                        cell_grid: np.ndarray,
                        coupling: np.ndarray | None = None,
                        load_ratio=None, include_iq: bool = False,
                        **gen_kwargs) -> EpisodeBatch:
    """``gen_episode_batch`` with the load-coupled interference floor.

    ``cell_grid``: (N, T + WINDOW) per-period cell of each UE. Loads are
    drawn here (not inside ``gen_episode_batch``) because the coupling
    needs them first. ``coupling=None`` generates exactly what the
    uncoupled call would."""
    n = len(cell_grid)
    lr = (rng.uniform(0.05, 1.0, n) if load_ratio is None
          else np.broadcast_to(np.asarray(load_ratio, float), (n,)))
    extra = (coupled_interference_mw(cell_grid, lr, coupling)
             if coupling is not None else None)
    return gen_episode_batch(scenarios, T, rng, load_ratio=lr,
                             include_iq=include_iq, extra_int_mw=extra,
                             **gen_kwargs)


@dataclasses.dataclass
class CellsResult:
    """A fleet result plus the cell topology it ran under."""

    fleet: FleetResult
    cell_idx: np.ndarray  # (N, T) per-period cell over the report window
    n_cells: int
    sched: Optional[SchedulerConfig]

    @property
    def served_mbps(self) -> np.ndarray:
        """(N, T) throughput actually served (full-grant truth scaled by
        the granted PRB share; the truth itself without a scheduler)."""
        if self.fleet.prb_share is None:
            return self.fleet.true_tp
        return tpmod.prb_scaled_mbps(self.fleet.true_tp,
                                     self.fleet.prb_share)

    def jain(self) -> float:
        """Fairness of the per-UE mean served throughput."""
        return jain_index(self.served_mbps.mean(axis=1))

    def share_sums(self) -> np.ndarray:
        """(C, T) per-cell PRB share totals — 1.0 for every non-empty cell
        if the scheduler conserves its budget (ones without a scheduler).
        Empty cells have no budget to conserve and report 1.0, so the
        whole array compares against 1.0 regardless of occupancy."""
        if self.fleet.prb_share is None:
            return np.ones((self.n_cells, self.cell_idx.shape[1]))
        onehot = self.cell_idx[..., None] == np.arange(self.n_cells)
        sums = (self.fleet.prb_share[..., None] * onehot).sum(axis=0).T
        return np.where(onehot.any(axis=0).T, sums, 1.0)


def simulate_cells(episode: EpisodeBatch, cell_grid: np.ndarray, table,
                   profile: SplitProfile, cfg: ControllerConfig, *,
                   sched: Optional[SchedulerConfig] = None,
                   n_cells: int | None = None, warm_split=None,
                   estimator=None, fixed_split: Optional[int] = None,
                   ue: DeviceProfile = UE_VM_2CORE,
                   server: DeviceProfile = EDGE_A40X2) -> CellsResult:
    """Run a multi-cell fleet through the engine.

    ``cell_grid`` may cover the full trace ((N, T + WINDOW), as built for
    the interference coupling) or just the report window ((N, T)); the
    scheduler consumes the report-window slice. ``sched=None`` keeps the
    engine's scheduler hook disabled — the exact PR-2 program."""
    grid = np.asarray(cell_grid)
    t_steps = episode.n_steps
    if grid.shape[1] == t_steps + WINDOW:
        grid = grid[:, WINDOW:]
    if grid.shape != (episode.n_ues, t_steps):
        raise ValueError(f"cell_grid shape {grid.shape} does not match "
                         f"({episode.n_ues}, {t_steps}) or the full trace")
    if n_cells is None:
        n_cells = int(grid.max()) + 1
    fleet = simulate_fleet(episode, table, profile, cfg,
                           warm_split=warm_split, estimator=estimator,
                           fixed_split=fixed_split, ue=ue, server=server,
                           sched=sched, cell_idx=grid, n_cells=n_cells)
    return CellsResult(fleet=fleet, cell_idx=grid, n_cells=n_cells,
                       sched=sched)
