"""Mesh-agnostic checkpointing with async save and elastic restore.

Arrays are written logically-unsharded (np.asarray gathers), one .npy per
leaf plus a JSON manifest; restore device_puts against WHATEVER sharding
tree the current mesh dictates — a checkpoint written on a 1x4 mesh
restores on 2x2 or on 512 devices (elastic scaling). Writes go to a temp
dir renamed atomically; a background thread makes saves non-blocking.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), x) for p, x in leaves], treedef


def save(ckpt_dir, step: int, tree, *, blocking: bool = True):
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    host = jax.tree.map(lambda x: np.asarray(x), tree)  # gather to host

    def _write():
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        pairs, _ = _flatten(host)
        manifest = {"step": step, "leaves": []}
        for i, (name, arr) in enumerate(pairs):
            fn = f"leaf_{i:05d}.npy"
            np.save(tmp / fn, arr)
            manifest["leaves"].append(
                {"key": name, "file": fn, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir) -> int | None:
    p = pathlib.Path(ckpt_dir)
    if not p.exists():
        return None
    steps = sorted(int(d.name.split("_")[1]) for d in p.iterdir()
                   if d.name.startswith("step_"))
    return steps[-1] if steps else None


def restore(ckpt_dir, step: int, like_tree, shardings=None):
    """Restore into the structure of like_tree; device_put per-leaf against
    shardings (same pytree) if given — this is where elastic re-sharding
    happens."""
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    arrays = [np.load(d / leaf["file"]) for leaf in manifest["leaves"]]
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    assert len(leaves) == len(arrays), (len(leaves), len(arrays))
    tree = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree


class CheckpointManager:
    """Keep-last-k manager with async saves."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.dir = pathlib.Path(ckpt_dir)
        self.keep = keep
        self._pending: threading.Thread | None = None

    def save(self, step: int, tree, *, blocking: bool = False):
        self.wait()
        self._pending = save(self.dir, step, tree, blocking=blocking)
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        if not self.dir.exists():
            return
        steps = sorted(int(d.name.split("_")[1]) for d in self.dir.iterdir()
                       if d.name.startswith("step_"))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def latest(self):
        return latest_step(self.dir)

    def restore(self, like_tree, shardings=None, step=None):
        step = step if step is not None else self.latest()
        assert step is not None, "no checkpoint found"
        return restore(self.dir, step, like_tree, shardings), step
