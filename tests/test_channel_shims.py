"""Direct shim coverage: the legacy single-sample channel APIs
(``interference_trace``, ``gen_episode``, ``kpm_window``, ``spectrogram``)
are thin shims over the batched paths. These tests pin each shim to the
matching slice of the batched output under an identical RNG stream, so the
shims cannot silently drift from the production path."""
import numpy as np

from repro.channel import iq as iqmod
from repro.channel import kpm as kpmmod
from repro.channel import scenarios as sc

N_SC_TEST = 16


def test_interference_trace_matches_batch_row():
    for scen in sc.SCENARIOS:
        one = sc.interference_trace(scen, 25, np.random.default_rng(1))
        batch = sc.interference_trace_batch([scen], 25,
                                            np.random.default_rng(1))
        assert one.shape == (25,)
        np.testing.assert_array_equal(one, batch[0])


def test_kpm_window_matches_batch_row():
    tr = sc.interference_trace("cci", 12, np.random.default_rng(2))
    one = kpmmod.kpm_window(tr, 0.4, np.random.default_rng(3), "cci")
    batch = kpmmod.kpm_window_batch(tr[None], 0.4, np.random.default_rng(3),
                                    "cci")
    assert one.shape == (12, len(kpmmod.KPMS_15))
    np.testing.assert_array_equal(one, batch[0])


def test_spectrogram_matches_batch_row():
    one = iqmod.spectrogram(-3.0, "jamming", 0.5, np.random.default_rng(4),
                            n_sc=N_SC_TEST)
    batch = iqmod.spectrogram_batch(np.array([-3.0]), "jamming", 0.5,
                                    np.random.default_rng(4), n_sc=N_SC_TEST)
    assert one.shape == (2, N_SC_TEST, iqmod.N_SYM)
    np.testing.assert_array_equal(one, batch[0])


def test_gen_episode_matches_batch_slices():
    """Every field of every ``Sample`` the legacy API emits must be the
    corresponding slice of the batched episode's arrays."""
    T = 5
    samples = sc.gen_episode("tdd", T, np.random.default_rng(5),
                             load_ratio=0.3, n_sc=N_SC_TEST)
    ep = sc.gen_episode_batch(["tdd"], T, np.random.default_rng(5),
                              load_ratio=0.3, n_sc=N_SC_TEST)
    assert len(samples) == T == ep.n_steps and ep.n_ues == 1
    wins = ep.kpm_windows(normalize=False)
    for t, s in enumerate(samples):
        assert s.scenario == "tdd"
        assert s.alloc_ratio == float(ep.alloc_ratio[0])
        assert s.tp_mbps == float(ep.tp_mbps[0, t])
        assert s.int_dbm == float(ep.int_dbm[0, sc.WINDOW + t])
        np.testing.assert_array_equal(s.kpms, wins[0, t])
        np.testing.assert_array_equal(s.iq, ep.iq[0, t])


def test_kpm_windows_gather_matches_view():
    """``kpm_windows(method="gather")`` must be BIT-equal to the default
    stride-trick view — normalized and raw — while actually owning its
    memory (C-contiguous, writable), which is what callers that mutate or
    serialize windows rely on."""
    ep = sc.gen_episode_batch(["cci", "jamming", "none"], 7,
                              np.random.default_rng(8), include_iq=False)
    for normalize in (True, False):
        view = ep.kpm_windows(normalize=normalize)
        gathered = ep.kpm_windows(normalize=normalize, method="gather")
        np.testing.assert_array_equal(gathered, view)
        assert gathered.flags.c_contiguous and gathered.flags.writeable
    try:
        ep.kpm_windows(method="nope")
    except ValueError as err:
        assert "method" in str(err)
    else:  # pragma: no cover - the guard must fire
        raise AssertionError("bad method accepted")


def test_gen_episode_draws_load_like_batch():
    """With ``load_ratio=None`` the shim must consume the RNG exactly like
    the batched path (same draw order), keeping mixed old/new pipelines
    reproducible."""
    samples = sc.gen_episode("cci", 3, np.random.default_rng(6),
                             n_sc=N_SC_TEST)
    ep = sc.gen_episode_batch(["cci"], 3, np.random.default_rng(6),
                              n_sc=N_SC_TEST)
    assert samples[0].alloc_ratio == float(ep.alloc_ratio[0])
    np.testing.assert_array_equal(samples[0].iq, ep.iq[0, 0])
