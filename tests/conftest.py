"""Give CPU test runs a few virtual devices so mesh/sharding paths are real.

This must execute before the first ``import jax`` of the session; pytest
imports conftest.py before collecting any test module, and none of the
active plugins import jax earlier. Single-device semantics are unchanged
for tests that never build a mesh (computations stay on device 0).
"""
import os

_FLAGS = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _FLAGS:
    os.environ["XLA_FLAGS"] = (
        _FLAGS + " --xla_force_host_platform_device_count=8"
    ).strip()
