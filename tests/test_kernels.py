"""Per-kernel correctness: Pallas (interpret=True on CPU) vs jnp oracles,
swept over shapes and dtypes. SSD property cases (chunk invariance,
random-shape kernel-vs-ref, exact state-carry associativity) run through
hypothesis when available, otherwise a fixed-seed sweep of the same
checks (the suite's standard pattern)."""
try:
    import hypothesis
    import hypothesis.strategies as st_
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dcor import dcor_kernel, pairwise_dists, pairwise_dists_ref
from repro.kernels.flash_attention import attention_ref, flash_attention, mha
from repro.kernels.quant import dequantize_rows, quantize_ref, quantize_rows
from repro.kernels.ssd import ssd, ssd_mixer, ssd_ref, ssd_step, ssd_step_ref
from repro.core.privacy import dcor as dcor_jnp


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ------------------------------------------------------------------ flash attn
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,dh,blocks", [(128, 64, (64, 64)),
                                         (256, 32, (128, 64)),
                                         (512, 64, (128, 128))])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 96)])
def test_flash_attention_matches_ref(dtype, S, dh, blocks, causal, window):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    BH = 3
    q = _rand(k1, (BH, S, dh), dtype)
    k = _rand(k2, (BH, S, dh), dtype)
    v = _rand(k3, (BH, S, dh), dtype)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        block_q=blocks[0], block_k=blocks[1])
    r = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


def test_mha_gqa_wrapper():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, KV, dh = 2, 128, 8, 2, 32
    q = _rand(k1, (B, S, H, dh), jnp.float32)
    k = _rand(k2, (B, S, KV, dh), jnp.float32)
    v = _rand(k3, (B, S, KV, dh), jnp.float32)
    o = mha(q, k, v, causal=True, block_q=64, block_k=64)
    r = mha(q, k, v, causal=True, use_kernel=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5,
                               rtol=1e-4)


# ------------------------------------------------------------------ dcor
@pytest.mark.parametrize("n,d,bn,bd", [(64, 128, 32, 64), (100, 300, 64, 128),
                                       (33, 70, 32, 512)])
def test_pairwise_dists_kernel(n, d, bn, bd):
    x = jax.random.normal(jax.random.PRNGKey(2), (n, d))
    got = pairwise_dists(x, block_n=bn, block_d=bd)
    ref = pairwise_dists_ref(x)
    # atol floor: ||a||^2+||b||^2-2ab cancels catastrophically near the
    # diagonal in BOTH implementations; sqrt amplifies to ~1e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-2,
                               rtol=1e-4)


def test_dcor_kernel_end_to_end():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, (48, 96))
    y = x @ jax.random.normal(k2, (96, 32)) * 0.5
    got = float(dcor_kernel(x, y))
    ref = float(dcor_jnp(x, y))
    assert abs(got - ref) < 1e-4


# ------------------------------------------------------------------ ssd
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,L,nh,hd,G,N", [(64, 16, 4, 16, 1, 8),
                                           (128, 32, 8, 32, 2, 16),
                                           (96, 96, 2, 8, 1, 4)])
def test_ssd_kernel_matches_ref(dtype, S, L, nh, hd, G, N):
    keys = jax.random.split(jax.random.PRNGKey(4), 4)
    B = 2
    x = _rand(keys[0], (B, S, nh, hd), dtype)
    dt = jax.nn.softplus(_rand(keys[1], (B, S, nh), jnp.float32)) * 0.5
    A = -jnp.exp(jax.random.normal(keys[2], (nh,)) * 0.3)
    Bm = _rand(keys[3], (B, S, G, N), dtype)
    Cm = _rand(keys[0], (B, S, G, N), dtype)
    y, st = ssd(x, dt, A, Bm, Cm, chunk=L)
    yr, str_ = ssd_ref(x, dt, A, Bm, Cm, L)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), atol=1e-3,
                               rtol=1e-3)


def _ssd_inputs(seed, B, S, nh, hd, G, N, dt_scale=0.5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))) * dt_scale
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    return x, dt, A, Bm, Cm


def test_ssd_chunk_size_invariance():
    """The chunked scan is a reassociation of one recurrence: any chunk
    size yields the same outputs and final state to float tolerance —
    kernel and oracle alike (the recurrent estimator leans on this when
    it pads sequences to a chunk multiple)."""
    x, dt, A, Bm, Cm = _ssd_inputs(11, 2, 256, 4, 16, 2, 8)
    y0, s0 = ssd_mixer(x, dt, A, Bm, Cm, chunk=64, use_kernel=False)
    for chunk in (128, 256):
        y, s = ssd_mixer(x, dt, A, Bm, Cm, chunk=chunk, use_kernel=False)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s0),
                                   atol=2e-5, rtol=2e-5)
    yk, sk = ssd_mixer(x, dt, A, Bm, Cm, chunk=64, use_kernel=True)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(y0),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(s0),
                               atol=1e-4, rtol=1e-4)


def test_ssd_step_scan_matches_mixer():
    """Scanning the O(1) step over a sequence from a zero state
    reproduces the chunked sequence pass — the contract that lets the
    recurrent estimator warm state with ``ssd_mixer`` and serve with
    ``ssd_step``."""
    x, dt, A, Bm, Cm = _ssd_inputs(12, 2, 48, 4, 8, 2, 4)
    y_seq, s_seq = ssd_mixer(x, dt, A, Bm, Cm, chunk=16, use_kernel=False)
    B, S, nh, hd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    state = jnp.zeros((B, G, nh // G, hd, N), jnp.float32)
    ys = []
    for t in range(S):
        y_t, state = ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t],
                              state)
        ys.append(np.asarray(y_t))
    np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_seq),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_seq),
                               atol=2e-5, rtol=2e-5)


def test_ssd_ref_gradients_finite_under_large_dt():
    """Regression: the intra-chunk decay matrix is masked BEFORE the exp.
    With large dt the masked upper triangle holds big positive exponents;
    exp-then-mask keeps the forward finite but leaks inf into the
    backward pass of the where() (inf * 0 = nan), which is exactly how
    the recurrent estimator's offline trainer used to NaN mid-run. The
    loss gradient w.r.t. every input must stay finite."""
    x, dt, A, Bm, Cm = _ssd_inputs(13, 1, 64, 2, 4, 1, 4, dt_scale=40.0)

    def loss(x, dt, Bm, Cm):
        y, s = ssd_mixer(x, dt, A, Bm, Cm, chunk=32, use_kernel=False)
        return jnp.sum(y**2) + jnp.sum(s**2)

    val, grads = jax.value_and_grad(loss, argnums=(0, 1, 2, 3))(
        x, dt, Bm, Cm)
    assert np.isfinite(float(val))
    for g in grads:
        assert np.all(np.isfinite(np.asarray(g)))


def _ssd_carry_case(seed):
    """Exact associativity on the integer-free path: A = 0 makes every
    decay exp(0) = 1 and small-integer inputs keep every f32 product and
    sum exactly representable, so splitting the sequence anywhere and
    carrying the state must be BIT-equal to the one-shot pass."""
    rng = np.random.default_rng(seed)
    B, S, nh, hd, G, N = 2, 32, 4, 8, 2, 4
    x = jnp.asarray(rng.integers(-3, 4, (B, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.integers(0, 3, (B, S, nh)), jnp.float32)
    A = jnp.zeros((nh,), jnp.float32)
    Bm = jnp.asarray(rng.integers(-2, 3, (B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.integers(-2, 3, (B, S, G, N)), jnp.float32)
    y_full, s_full = ssd_ref(x, dt, A, Bm, Cm, 16)
    h = S // 2
    _, s_half = ssd_ref(x[:, :h], dt[:, :h], A, Bm[:, :h], Cm[:, :h], 16)
    state = s_half
    for t in range(h, S):
        y_t, state = ssd_step_ref(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t],
                                  state)
        np.testing.assert_array_equal(np.asarray(y_t),
                                      np.asarray(y_full[:, t]))
    np.testing.assert_array_equal(np.asarray(state), np.asarray(s_full))


def _ssd_kernel_vs_ref_case(nc, L, nh, hd, G, N, seed):
    x, dt, A, Bm, Cm = _ssd_inputs(seed, 2, nc * L, nh, hd, G, N)
    y, s = ssd(x, dt, A, Bm, Cm, chunk=L)
    yr, sr = ssd_ref(x, dt, A, Bm, Cm, L)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-3,
                               rtol=1e-3)


if HAVE_HYPOTHESIS:
    @hypothesis.settings(max_examples=10, deadline=None)
    @hypothesis.given(seed=st_.integers(0, 999))
    def test_ssd_state_carry_exact_property(seed):
        _ssd_carry_case(seed)

    @hypothesis.settings(max_examples=8, deadline=None)
    @hypothesis.given(nc=st_.integers(1, 3), L=st_.sampled_from([8, 16, 32]),
                      hpg=st_.sampled_from([1, 2, 4]),
                      hd=st_.sampled_from([8, 16]),
                      G=st_.sampled_from([1, 2]),
                      N=st_.sampled_from([4, 8]),
                      seed=st_.integers(0, 99))
    def test_ssd_kernel_matches_ref_property(nc, L, hpg, hd, G, N, seed):
        _ssd_kernel_vs_ref_case(nc, L, G * hpg, hd, G, N, seed)
else:  # pragma: no cover - depends on environment
    @pytest.mark.parametrize("seed", [0, 7, 42])
    def test_ssd_state_carry_exact_property(seed):
        _ssd_carry_case(seed)

    @pytest.mark.parametrize("nc,L,nh,hd,G,N,seed",
                             [(1, 8, 2, 8, 1, 4, 0), (2, 16, 4, 16, 2, 8, 1),
                              (3, 32, 8, 8, 2, 4, 2)])
    def test_ssd_kernel_matches_ref_property(nc, L, nh, hd, G, N, seed):
        _ssd_kernel_vs_ref_case(nc, L, nh, hd, G, N, seed)


# ------------------------------------------------------------------ quant
@pytest.mark.parametrize("shape", [(32, 64), (100, 128), (7, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_kernel_matches_ref(shape, dtype):
    x = (_rand(jax.random.PRNGKey(5), shape, dtype) * 4).astype(dtype)
    q, s = quantize_rows(x)
    qr, sr = quantize_ref(x.reshape(-1, shape[-1]))
    np.testing.assert_array_equal(np.asarray(q).reshape(qr.shape),
                                  np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s).reshape(sr.shape),
                               np.asarray(sr), rtol=1e-5)
    y = dequantize_rows(q, s)
    rel = float(jnp.max(jnp.abs(y.astype(jnp.float32) -
                                x.astype(jnp.float32))))
    assert rel <= float(s.max()) * 1.01


def test_quant_roundtrip_error_bound():
    """|x - dq(q(x))| <= scale/2 per element (hypothesis-style bound)."""
    for seed in range(5):
        x = jax.random.normal(jax.random.PRNGKey(seed), (16, 32)) * (seed + 1)
        q, s = quantize_rows(x)
        y = dequantize_rows(q, s, jnp.float32)
        err = jnp.abs(y - x)
        assert float((err - s / 2 - 1e-6).max()) <= 0.0
