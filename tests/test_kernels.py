"""Per-kernel correctness: Pallas (interpret=True on CPU) vs jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dcor import dcor_kernel, pairwise_dists, pairwise_dists_ref
from repro.kernels.flash_attention import attention_ref, flash_attention, mha
from repro.kernels.quant import dequantize_rows, quantize_ref, quantize_rows
from repro.kernels.ssd import ssd, ssd_ref
from repro.core.privacy import dcor as dcor_jnp


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ------------------------------------------------------------------ flash attn
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,dh,blocks", [(128, 64, (64, 64)),
                                         (256, 32, (128, 64)),
                                         (512, 64, (128, 128))])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 96)])
def test_flash_attention_matches_ref(dtype, S, dh, blocks, causal, window):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    BH = 3
    q = _rand(k1, (BH, S, dh), dtype)
    k = _rand(k2, (BH, S, dh), dtype)
    v = _rand(k3, (BH, S, dh), dtype)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        block_q=blocks[0], block_k=blocks[1])
    r = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


def test_mha_gqa_wrapper():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, KV, dh = 2, 128, 8, 2, 32
    q = _rand(k1, (B, S, H, dh), jnp.float32)
    k = _rand(k2, (B, S, KV, dh), jnp.float32)
    v = _rand(k3, (B, S, KV, dh), jnp.float32)
    o = mha(q, k, v, causal=True, block_q=64, block_k=64)
    r = mha(q, k, v, causal=True, use_kernel=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5,
                               rtol=1e-4)


# ------------------------------------------------------------------ dcor
@pytest.mark.parametrize("n,d,bn,bd", [(64, 128, 32, 64), (100, 300, 64, 128),
                                       (33, 70, 32, 512)])
def test_pairwise_dists_kernel(n, d, bn, bd):
    x = jax.random.normal(jax.random.PRNGKey(2), (n, d))
    got = pairwise_dists(x, block_n=bn, block_d=bd)
    ref = pairwise_dists_ref(x)
    # atol floor: ||a||^2+||b||^2-2ab cancels catastrophically near the
    # diagonal in BOTH implementations; sqrt amplifies to ~1e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-2,
                               rtol=1e-4)


def test_dcor_kernel_end_to_end():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, (48, 96))
    y = x @ jax.random.normal(k2, (96, 32)) * 0.5
    got = float(dcor_kernel(x, y))
    ref = float(dcor_jnp(x, y))
    assert abs(got - ref) < 1e-4


# ------------------------------------------------------------------ ssd
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,L,nh,hd,G,N", [(64, 16, 4, 16, 1, 8),
                                           (128, 32, 8, 32, 2, 16),
                                           (96, 96, 2, 8, 1, 4)])
def test_ssd_kernel_matches_ref(dtype, S, L, nh, hd, G, N):
    keys = jax.random.split(jax.random.PRNGKey(4), 4)
    B = 2
    x = _rand(keys[0], (B, S, nh, hd), dtype)
    dt = jax.nn.softplus(_rand(keys[1], (B, S, nh), jnp.float32)) * 0.5
    A = -jnp.exp(jax.random.normal(keys[2], (nh,)) * 0.3)
    Bm = _rand(keys[3], (B, S, G, N), dtype)
    Cm = _rand(keys[0], (B, S, G, N), dtype)
    y, st = ssd(x, dt, A, Bm, Cm, chunk=L)
    yr, str_ = ssd_ref(x, dt, A, Bm, Cm, L)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), atol=1e-3,
                               rtol=1e-3)


# ------------------------------------------------------------------ quant
@pytest.mark.parametrize("shape", [(32, 64), (100, 128), (7, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_kernel_matches_ref(shape, dtype):
    x = (_rand(jax.random.PRNGKey(5), shape, dtype) * 4).astype(dtype)
    q, s = quantize_rows(x)
    qr, sr = quantize_ref(x.reshape(-1, shape[-1]))
    np.testing.assert_array_equal(np.asarray(q).reshape(qr.shape),
                                  np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s).reshape(sr.shape),
                               np.asarray(sr), rtol=1e-5)
    y = dequantize_rows(q, s)
    rel = float(jnp.max(jnp.abs(y.astype(jnp.float32) -
                                x.astype(jnp.float32))))
    assert rel <= float(s.max()) * 1.01


def test_quant_roundtrip_error_bound():
    """|x - dq(q(x))| <= scale/2 per element (hypothesis-style bound)."""
    for seed in range(5):
        x = jax.random.normal(jax.random.PRNGKey(seed), (16, 32)) * (seed + 1)
        q, s = quantize_rows(x)
        y = dequantize_rows(q, s, jnp.float32)
        err = jnp.abs(y - x)
        assert float((err - s / 2 - 1e-6).max()) <= 0.0
