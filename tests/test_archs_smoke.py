"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs; plus prefill->decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import init_cache, init_params
from repro.models.lm import decode_step, forward, lm_loss

BATCH, SEQ = 2, 16


def make_batch(cfg, key, batch=BATCH, seq=SEQ):
    kt, kl, kv = jax.random.split(key, 3)
    b = {}
    if cfg.frame_input_dim:
        b["frames"] = jax.random.normal(kt, (batch, seq, cfg.frame_input_dim),
                                        jnp.float32)
    else:
        b["tokens"] = jax.random.randint(kt, (batch, seq), 0, cfg.vocab)
    b["labels"] = jax.random.randint(kl, (batch, seq), 0, cfg.vocab)
    if cfg.vision_dim:
        b["vision"] = jax.random.normal(
            kv, (batch, cfg.vision_tokens, cfg.vision_dim), jnp.float32)
    return b


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, key)
    logits, aux, _ = jax.jit(
        lambda p, b: forward(cfg, p, b, mode="train", remat="none")
    )(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_grads_finite(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = make_batch(cfg, key)

    def loss_fn(p):
        return lm_loss(cfg, p, batch, remat="full")[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all()


DECODE_CONSISTENCY = ["granite-8b", "gemma3-27b", "recurrentgemma-2b",
                      "mamba2-370m", "qwen2-72b", "stablelm-1.6b"]


@pytest.mark.parametrize("arch", DECODE_CONSISTENCY)
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce full-forward logits."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    batch = make_batch(cfg, key)
    full_logits, _, _ = forward(cfg, params, batch, mode="train", remat="none")

    n_pre = SEQ - 2
    pre = {k: v[:, :n_pre] if v.ndim > 1 and v.shape[1] == SEQ else v
           for k, v in batch.items()}
    _, _, cache = forward(cfg, params, pre, mode="prefill", logits_mode="last",
                          max_seq=SEQ)
    logits_list = []
    for t in range(n_pre, SEQ):
        tok = batch["tokens"][:, t : t + 1]
        lg, cache = decode_step(cfg, params, cache, tok,
                                jnp.asarray(t, jnp.int32))
        logits_list.append(lg[:, 0])
    dec = jnp.stack(logits_list, axis=1).astype(jnp.float32)
    ref = full_logits[:, n_pre:].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=0.15, atol=0.15)


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "llama4-scout-17b-a16e",
                                  "llama-3.2-vision-90b", "hubert-xlarge"])
def test_decode_or_encoder_finite(arch):
    """MoE/VLM decode runs & is finite (routing drops preclude exactness);
    encoder archs only check forward (no decode step)."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    batch = make_batch(cfg, key)
    if cfg.is_encoder:
        logits, _, _ = forward(cfg, params, batch, mode="train", remat="none")
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        return
    _, _, cache = forward(cfg, params, batch, mode="prefill",
                          logits_mode="last")
    lg, cache2 = decode_step(cfg, params, cache, batch["tokens"][:, :1],
                             jnp.asarray(SEQ, jnp.int32))
    assert lg.shape == (BATCH, 1, cfg.vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
