"""CheckpointManager round-trip coverage (repro.checkpoint).

The online trainer (``repro.sim.online``) leans on three behaviours that
were previously untested: round-tripping an estimator param pytree
through save/restore, the ``restore(..., shardings=)`` elastic-resharding
path, and ``keep=`` pruning of old steps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.estimator.model import EstimatorConfig, init_estimator
from repro.launch.mesh import make_host_mesh

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs >= 8 (virtual) devices")


def tiny_params(seed: int = 0):
    e = EstimatorConfig(n_sc=16, lstm_hidden=8, hidden=8)
    return init_estimator(e, jax.random.PRNGKey(seed))


def assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.shape == y.shape and x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_estimator_params_roundtrip(tmp_path):
    """Save -> restore reproduces the estimator pytree exactly (structure,
    dtypes, values), via both the manager and the bare functions."""
    params = tiny_params()
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, params)  # async by default — restore must wait correctly
    mgr.wait()
    assert mgr.latest() == 1
    restored, step = mgr.restore(params)
    assert step == 1
    assert_trees_equal(params, restored)
    # bare-function path too
    save(tmp_path, 2, params, blocking=True)
    assert latest_step(tmp_path) == 2
    assert_trees_equal(params, restore(tmp_path, 2, params))


@multi_device
def test_restore_with_shardings_resharding(tmp_path):
    """restore(..., shardings=) device_puts each leaf against the given
    sharding tree — a checkpoint written unsharded comes back laid out for
    whatever mesh serves it (elastic restore). The online trainer restores
    replicated onto the serving mesh."""
    params = tiny_params()
    save(tmp_path, 0, params, blocking=True)
    mesh = make_host_mesh(8, 1)
    replicated = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    restored = restore(tmp_path, 0, params, shardings=replicated)
    assert_trees_equal(params, restored)
    for leaf in jax.tree.leaves(restored):
        assert leaf.sharding == NamedSharding(mesh, P())
    # a non-trivially sharded leaf tree works too: shard the lstm input
    # projection over the data axis, everything else replicated
    def spec(path, x):
        key = jax.tree_util.keystr(path)
        if key == "['lstm']['wx']":
            return NamedSharding(mesh, P(None, "data"))
        return NamedSharding(mesh, P())
    mixed = jax.tree_util.tree_map_with_path(spec, params)
    restored2, step = CheckpointManager(tmp_path).restore(params,
                                                          shardings=mixed)
    assert step == 0
    assert_trees_equal(params, restored2)
    assert restored2["lstm"]["wx"].sharding.spec == P(None, "data")


def test_keep_pruning_and_latest(tmp_path):
    """keep=k retains only the newest k steps; latest()/restore() always
    point at the newest surviving one."""
    params = {"w": jnp.arange(4.0)}
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in range(5):
        mgr.save(s, {"w": jnp.arange(4.0) + s}, blocking=True)
    mgr.wait()
    dirs = sorted(d.name for d in tmp_path.iterdir()
                  if d.name.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    assert mgr.latest() == 4
    restored, step = mgr.restore(params)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(4.0) + 4)
    # pruned steps are really gone
    with pytest.raises(FileNotFoundError):
        restore(tmp_path, 0, params)


def test_async_save_then_restore(tmp_path):
    """A non-blocking save followed by manager.restore() must see the
    finished checkpoint (save/wait ordering)."""
    params = tiny_params(seed=3)
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(7, params, blocking=False)
    mgr.wait()
    restored, step = mgr.restore(params)
    assert step == 7
    assert_trees_equal(params, restored)
