"""Mesh-sharded fleet estimator serving (repro.sim.serving).

Pins the two load-bearing properties of the serving subsystem on the
host's virtual-device mesh: (1) the sharded per-period program is
numerically interchangeable with the unsharded ``predict`` path
(allclose), and (2) at lowering level the UE batch axis is *actually*
sharded over the mesh's data axis, not silently replicated. Plus the EP
mesh variant: the reserved ``experts`` logical axis finally resolves to
a physical ``expert`` axis.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.channel import scenarios as sc
from repro.dist import sharding as sh
from repro.estimator.model import EstimatorConfig, init_estimator
from repro.launch.mesh import make_host_mesh
from repro.sim import estimate_fleet, make_serving_mesh
from repro.sim.serving import ServingMesh, serving_program

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs >= 8 (virtual) devices")

N_SC_TEST = 16


def tiny_estimator(seed: int = 0):
    e = EstimatorConfig(n_sc=N_SC_TEST, lstm_hidden=8, hidden=8)
    return e, init_estimator(e, jax.random.PRNGKey(seed))


def episode(n: int, T: int = 3, seed: int = 5):
    rng = np.random.default_rng(seed)
    names = np.asarray(sc.SCENARIOS)[np.arange(n) % len(sc.SCENARIOS)]
    return sc.gen_episode_batch(names, T, rng, n_sc=N_SC_TEST)


# ------------------------------------------------------------- equivalence
@multi_device
def test_sharded_matches_unsharded():
    """Mesh-sharded estimate_fleet == unsharded path (allclose), with the
    batch evenly split over an 8-way data axis."""
    e, params = tiny_estimator()
    ep = episode(8)
    base = estimate_fleet(ep, (e, params))
    shd = estimate_fleet(ep, (e, params), serving=make_serving_mesh("8x1"))
    assert shd.shape == base.shape == (8, 3)
    np.testing.assert_allclose(shd, base, rtol=1e-5, atol=1e-4)


@multi_device
def test_sharded_uneven_batch_falls_back():
    """A fleet size not divisible by the data axis replicates (the
    Ruleset divisibility fallback) instead of erroring, and still
    matches."""
    e, params = tiny_estimator()
    ep = episode(6)
    base = estimate_fleet(ep, (e, params))
    shd = estimate_fleet(ep, (e, params), serving=make_serving_mesh("4x2"))
    np.testing.assert_allclose(shd, base, rtol=1e-5, atol=1e-4)


@multi_device
def test_simulate_fleet_composes_with_serving():
    """The engine hook: simulate_fleet(estimator=..., serving=...) runs the
    sharded estimator under the controller scan and feeds controllers the
    same estimates as the unsharded run."""
    from repro.core.controller import ControllerConfig
    from repro.models.vgg import FULL, vgg_split_profile
    from repro.core.pso import LookupTable
    from repro.sim import simulate_fleet

    e, params = tiny_estimator()
    ep = episode(8, T=4)
    prof = vgg_split_profile(FULL)
    table = LookupTable(ue_name="t", table=np.full(41, 3, np.int32),
                        tp_min_mbps=np.zeros(len(prof.data_bytes)),
                        feasible_prefilter=np.ones(len(prof.data_bytes),
                                                   bool))
    cfg = ControllerConfig(0.5, 2, 3)
    base = simulate_fleet(ep, table, prof, cfg, estimator=(e, params))
    shd = simulate_fleet(ep, table, prof, cfg, estimator=(e, params),
                         serving=make_serving_mesh("8x1"))
    np.testing.assert_allclose(shd.est_tp, base.est_tp, rtol=1e-5, atol=1e-4)
    assert shd.splits.shape == base.splits.shape == (8, 4)


# ---------------------------------------------------------------- lowering
@multi_device
def test_lowering_shards_ue_batch_axis():
    """The per-period program's HLO carries an 8-way tiling on dim 0 of the
    batch inputs (mesh data=8): the UE batch axis is actually sharded."""
    e, params = tiny_estimator()
    serving = make_serving_mesh("8x1")
    assert dict(serving.mesh.shape) == {"data": 8, "model": 1}
    fn = serving_program(e, serving)
    n = 8
    pabs = jax.eval_shape(lambda: params)
    lowered = fn.lower(
        pabs,
        jax.ShapeDtypeStruct((n, e.window, e.n_kpms), jnp.float32),
        jax.ShapeDtypeStruct((n, 2, e.n_sc, e.n_sym), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32))
    text = lowered.as_text()
    # iq is rank 4, kpms rank 3; both must pick up dim-0 tiling over the
    # 8-way data axis
    assert "devices=[8,1,1,1]<=[8]" in text, "iq batch dim not sharded"
    assert "devices=[8,1,1]<=[8]" in text, "kpms batch dim not sharded"


@multi_device
def test_put_commits_batch_sharding():
    """dist.sharding.put places a host array with the batch rule's
    NamedSharding (and is identity outside a ruleset)."""
    x = jnp.ones((8, 4))
    assert sh.put(x, ("batch", None)) is x  # no active ruleset
    serving = make_serving_mesh("8x1")
    with sh.use_rules(serving.mesh):
        y = sh.put(x, ("batch", None))
    assert y.sharding.spec == P("data", None)


# ----------------------------------------------------------------- EP mesh
@multi_device
def test_ep_host_mesh_carries_expert_axis():
    """make_host_mesh(expert=) yields a (data, expert, model) mesh on which
    the 'experts' logical axis resolves — the first mesh to carry it."""
    mesh = make_host_mesh(2, 2, expert=2)
    assert dict(mesh.shape) == {"data": 2, "expert": 2, "model": 2}
    with sh.use_rules(mesh) as rs:
        assert rs.spec(("experts", "ff", "embed"), (4, 8, 16)) == P(
            "expert", "model", None)
        assert rs.axis_size("experts") == 2
        w = sh.put(jnp.ones((4, 8, 16)), ("experts", "ff", None))
    assert w.sharding.spec == P("expert", "model", None)


def test_ep_axis_absent_on_2d_mesh_falls_back():
    """On a plain (data, model) mesh the experts rule still silently
    replicates — the PR-1 fallback contract is unchanged."""
    mesh = make_host_mesh(2, 2)
    with sh.use_rules(mesh) as rs:
        assert rs.spec(("experts", "ff"), (4, 8))[0] is None
        assert rs.axis_size("experts") == 1


def test_make_host_mesh_expert_clamps():
    """expert requests clamp like data/model: a 2-axis mesh comes back
    when the clamped expert size is 1."""
    mesh = make_host_mesh(len(jax.devices()), 1, expert=1)
    assert "expert" not in mesh.shape


# ------------------------------------------------------------- mesh parsing
@multi_device
def test_make_serving_mesh_specs():
    s = make_serving_mesh("4x2")
    assert dict(s.mesh.shape) == {"data": 4, "model": 2}
    assert s.n_chips == 8 and s.describe() == "data=4,model=2"
    s3 = make_serving_mesh("2x2x2")
    assert dict(s3.mesh.shape) == {"data": 2, "expert": 2, "model": 2}
    with pytest.raises(ValueError):
        make_serving_mesh("2x2x2x2")


def test_serving_mesh_is_cache_key():
    """ServingMesh + EstimatorConfig key the program cache: same deployment
    -> same compiled program object."""
    e, _ = tiny_estimator()
    s1 = make_serving_mesh("1x1")
    s2 = make_serving_mesh("1x1")
    assert serving_program(e, s1) is serving_program(e, s2)
