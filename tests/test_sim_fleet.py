"""Fleet engine tests: functional controller == sequential class (property
test under vmap+scan), StackedLookupTable.query_many == looped query,
batched episode generation, and engine-vs-looped equivalence. Property
tests run through hypothesis when available, otherwise a fixed-seed sweep
of the same checks."""
try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

import numpy as np
import pytest

from repro.channel import scenarios as sc
from repro.core.controller import (AdaptiveSplitController, ControllerConfig,
                                   NO_SPLIT, PENDING_NONE, controller_init,
                                   controller_step)
from repro.core.energy import EDGE_A40X2, UE_VM_2CORE
from repro.core.objective import Constraints, Weights
from repro.core.pso import LookupTable, StackedLookupTable, pso_vectorized
from repro.models.vgg import FULL, vgg_split_profile
from repro.sim import run_controllers, simulate_fleet, simulate_fleet_looped

N_SC_TEST = 16


def random_stacked(rng, n_ues, width=40, n_splits=12) -> StackedLookupTable:
    """Random lookup rows: bucket 0 always NO_SPLIT (the sweep starts at
    1 Mbps), other buckets may be NO_SPLIT or any split index."""
    tables = rng.integers(-1, n_splits, (n_ues, width + 1)).astype(np.int32)
    tables[:, 0] = NO_SPLIT
    return StackedLookupTable(
        ue_names=[f"ue{i}" for i in range(n_ues)], tables=tables,
        tp_min_mbps=np.zeros((n_ues, n_splits)),
        feasible_prefilter=np.ones((n_ues, n_splits), bool))


def reference_update(table, cfg, state, tp):
    """The original stateful-class update logic, transcribed with float32
    EWMA arithmetic (what the functional core uses). ``state`` is the dict
    (ewma|None, current, pending|None, count)."""
    a = np.float32(cfg.ewma_alpha)
    tp = np.float32(tp)
    ewma = (tp if state["ewma"] is None
            else np.float32(a * tp + np.float32(1.0 - cfg.ewma_alpha)
                            * state["ewma"]))
    state["ewma"] = ewma
    bucket = int(np.clip(np.round(ewma), 0, len(table) - 1))
    proposal = int(table[bucket])
    if proposal == NO_SPLIT:
        proposal = cfg.fallback_split
    if proposal != state["current"]:
        if proposal == state["pending"]:
            state["count"] += 1
        else:
            state["pending"], state["count"] = proposal, 1
        if state["count"] >= cfg.hysteresis_steps:
            state["current"] = proposal
            state["pending"], state["count"] = None, 0
    else:
        state["pending"], state["count"] = None, 0
    return state["current"]


def _check_batched_matches_sequential(seed, alpha, hysteresis, fallback):
    """vmap+scan over the fleet == per-UE sequential class == the original
    class logic, step for step."""
    rng = np.random.default_rng(seed)
    n_ues, t_steps = 5, 40
    stacked = random_stacked(rng, n_ues)
    cfg = ControllerConfig(ewma_alpha=alpha, hysteresis_steps=hysteresis,
                           fallback_split=fallback)
    tps = rng.uniform(0.0, stacked.tables.shape[1] + 5.0, (n_ues, t_steps))
    batched = run_controllers(stacked.tables, tps, cfg, NO_SPLIT)
    for u in range(n_ues):
        ctl = AdaptiveSplitController(stacked.row(u), cfg)
        ref = {"ewma": None, "current": NO_SPLIT, "pending": None, "count": 0}
        for t in range(t_steps):
            got = ctl.update(float(tps[u, t]))
            want = reference_update(stacked.tables[u], cfg, ref,
                                    float(tps[u, t]))
            assert got == want == batched[u, t], (u, t, got, want,
                                                  batched[u, t])
        # internal hysteresis state must agree too, not just the output
        assert (ctl.pending_split is None) == (ref["pending"] is None)
        if ref["pending"] is not None:
            assert ctl.pending_split == ref["pending"]
        assert ctl.pending_count == ref["count"]


def _check_query_many_matches_query(seed):
    rng = np.random.default_rng(seed)
    stacked = random_stacked(rng, 7)
    tps = rng.uniform(-1.0, stacked.tables.shape[1] + 10.0, 7)
    tps[0] = 0.2  # 0-bucket NO_SPLIT edge: must not clamp up to bucket 1
    got = stacked.query_many(tps)
    want = [stacked.row(u).query(float(tps[u])) for u in range(7)]
    np.testing.assert_array_equal(got, want)
    assert got[0] == NO_SPLIT


if HAVE_HYPOTHESIS:
    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.given(seed=st.integers(0, 10_000), alpha=st.floats(0.05, 1.0),
                      hysteresis=st.integers(1, 4),
                      fallback=st.integers(-1, 11))
    def test_batched_controller_matches_sequential(seed, alpha, hysteresis,
                                                   fallback):
        _check_batched_matches_sequential(seed, alpha, hysteresis, fallback)

    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.given(seed=st.integers(0, 10_000))
    def test_query_many_matches_query(seed):
        _check_query_many_matches_query(seed)
else:
    @pytest.mark.parametrize("seed,alpha,hysteresis,fallback", [
        (0, 0.5, 2, -1), (1, 1.0, 1, 0), (2, 0.6, 2, 5), (3, 0.05, 3, -1),
        (4, 0.9, 4, 11), (5, 0.3, 2, 2), (6, 0.75, 1, -1), (7, 0.6, 3, 7),
    ])
    def test_batched_controller_matches_sequential(seed, alpha, hysteresis,
                                                   fallback):
        _check_batched_matches_sequential(seed, alpha, hysteresis, fallback)

    @pytest.mark.parametrize("seed", range(8))
    def test_query_many_matches_query(seed):
        _check_query_many_matches_query(seed)


def test_controller_reset_and_warm_start():
    rng = np.random.default_rng(0)
    stacked = random_stacked(rng, 1)
    ctl = AdaptiveSplitController(stacked.row(0), ControllerConfig(
        ewma_alpha=1.0, hysteresis_steps=1))
    ctl.update(20)
    assert ctl.switches and ctl.tp_ewma is not None
    ctl.reset(warm_split=9)
    assert ctl.current_split == 9
    assert ctl.tp_ewma is None and ctl.switches == []
    assert ctl.pending_split is None and ctl.pending_count == 0
    state = controller_init(warm_split=4, batch_shape=(3,))
    assert state.current_split.shape == (3,)
    assert int(state.pending_split[0]) == PENDING_NONE


def test_controller_step_single_matches_class():
    """Scalar controller_step drives the class: one more direct pin."""
    rng = np.random.default_rng(3)
    stacked = random_stacked(rng, 1)
    cfg = ControllerConfig(ewma_alpha=0.6, hysteresis_steps=2,
                           fallback_split=3)
    ctl = AdaptiveSplitController(stacked.row(0), cfg)
    state = controller_init()
    for tp in rng.uniform(0, 45, 25):
        state, split = controller_step(stacked.tables[0], state, float(tp),
                                       cfg=cfg)
        assert int(split) == ctl.update(float(tp))
    assert int(state.step) == 25


def test_stack_rejects_mixed_tp_max():
    a = LookupTable("a", np.full(11, NO_SPLIT, np.int32), np.zeros(3),
                    np.ones(3, bool))
    b = LookupTable("b", np.full(21, NO_SPLIT, np.int32), np.zeros(3),
                    np.ones(3, bool))
    with pytest.raises(AssertionError, match="mixed tp_max"):
        StackedLookupTable.stack([a, b])
    st2 = StackedLookupTable.stack([a, a])
    assert st2.n_ues == 2 and st2.row(1).ue_name == "a"


# --------------------------------------------------------------- episodes
def test_gen_episode_batch_shapes_and_labels():
    rng = np.random.default_rng(1)
    scen = np.array(["none", "jamming", "cci", "tdd", "jamming"])
    ep = sc.gen_episode_batch(scen, 6, rng, n_sc=N_SC_TEST)
    assert ep.n_ues == 5 and ep.n_steps == 6
    assert ep.int_dbm.shape == (5, 6 + sc.WINDOW)
    assert ep.kpms.shape == (5, 6 + sc.WINDOW, 15)
    assert ep.iq.shape == (5, 6, 2, N_SC_TEST, 14)
    assert ep.kpm_windows().shape == (5, 6, sc.WINDOW, 15)
    # labels are the ground-truth curve evaluated on the trace
    from repro.channel import throughput as tp
    np.testing.assert_allclose(
        ep.tp_mbps, tp.max_throughput_mbps(ep.int_dbm[:, sc.WINDOW:]))
    # the 'none' row is pinned at the interference floor
    assert np.all(ep.int_dbm[0] == -60.0)
    np.testing.assert_array_equal(ep.scenario_idx, [0, 1, 2, 3, 1])


def test_kpm_windows_match_sample_windows():
    """The strided window view must reproduce the per-sample window slices
    the sequential path hands the estimator."""
    rng = np.random.default_rng(2)
    ep = sc.gen_episode_batch(np.array(["cci"]), 5, rng, n_sc=N_SC_TEST)
    wins = ep.kpm_windows(normalize=False)
    for t in range(5):
        np.testing.assert_array_equal(
            wins[0, t], ep.kpms[0, t:t + sc.WINDOW])


def test_gen_episode_shim_matches_batch_layout():
    rng = np.random.default_rng(3)
    eps = sc.gen_episode("tdd", 4, rng, n_sc=N_SC_TEST)
    assert len(eps) == 4
    assert eps[0].kpms.shape == (sc.WINDOW, 15)
    assert eps[0].iq.shape == (2, N_SC_TEST, 14)
    assert eps[0].scenario == "tdd"


def test_gen_episode_batch_handover_grid():
    """Mid-episode scenario handover: per-step scenario grid changes the
    interference trace and KPM overlap after the handover point."""
    rng = np.random.default_rng(4)
    T, t_h = 8, sc.WINDOW + 4
    grid = np.full((3, T + sc.WINDOW), "none", dtype=object)
    grid[1:, t_h:] = "jamming"
    ep = sc.gen_episode_batch(grid, T, rng, load_ratio=0.5, n_sc=N_SC_TEST,
                              include_iq=False)
    assert ep.iq is None
    # pre-handover everything sits at the floor; post-handover rows 1-2
    # carry jamming interference while row 0 stays quiet
    assert np.all(ep.int_dbm[:, :t_h] == -60.0)
    assert np.all(ep.int_dbm[0] == -60.0)
    assert ep.int_dbm[1:, t_h:].max() > -60.0


def test_gen_dataset_balanced_and_shuffled():
    rng = np.random.default_rng(5)
    ds = sc.gen_dataset(25, rng, episode_len=10, n_sc=N_SC_TEST)
    counts = np.bincount(ds["scenario"], minlength=4)
    assert np.all(counts >= 25)
    assert ds["kpms"].shape == (counts.sum(), sc.WINDOW, 15)
    assert ds["iq"].dtype == np.float32
    # shuffled: scenarios must not come out in generation order
    assert len(np.unique(ds["scenario"][:10])) > 1


# --------------------------------------------------------------- engine
def test_simulate_fleet_matches_looped_mixed_fleet():
    """Vectorized engine == legacy loop on a mixed-scenario, heterogeneous
    fleet (bit-identical splits, float-identical metrics)."""
    rng = np.random.default_rng(6)
    prof = vgg_split_profile(FULL)
    cons = Constraints(rho_max=0.92, tau_max_s=6.0, e_max_j=40.0)
    table = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2,
                           Weights(1.0, 0.15, 0.1), cons, 130)
    scen = np.asarray(sc.SCENARIOS)[np.arange(8) % 4]
    ep = sc.gen_episode_batch(scen, 10, rng, include_iq=False)
    cfg = ControllerConfig(ewma_alpha=0.6, hysteresis_steps=2,
                           fallback_split=int(table.query(130.0)))
    fixed = int(table.query(130.0))
    vec = simulate_fleet(ep, table, prof, cfg, fixed_split=fixed)
    loop = simulate_fleet_looped(ep, table, prof, cfg, fixed_split=fixed)
    np.testing.assert_array_equal(vec.splits, loop.splits)
    for f in ("delay_s", "privacy", "energy_j"):
        np.testing.assert_allclose(getattr(vec, f), getattr(loop, f),
                                   rtol=1e-12)
        np.testing.assert_allclose(getattr(vec.fixed, f),
                                   getattr(loop.fixed, f), rtol=1e-12)
    means = vec.scenario_means(ep.scenario_idx)
    assert set(means) == set(sc.SCENARIOS)


def test_simulate_fleet_stacked_tables_per_ue():
    """Per-UE tables: a fleet where half the UEs run a privacy-tightened
    table must take different decisions from the shared-table half."""
    rng = np.random.default_rng(7)
    prof = vgg_split_profile(FULL)
    loose = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2,
                           Weights(1.0, 0.0, 0.0),
                           Constraints(rho_max=0.98), 60)
    tight = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2,
                           Weights(1.0, 0.0, 0.0),
                           Constraints(rho_max=0.5), 60)
    assert not np.array_equal(loose.table, tight.table)
    stacked = StackedLookupTable.stack([loose, tight, loose, tight])
    ep = sc.gen_episode_batch(np.array(["cci"] * 4), 8, rng,
                              load_ratio=0.9, include_iq=False)
    # identical traces for all four UEs: decisions differ only via tables
    tr = np.tile(ep.int_dbm[:1], (4, 1))
    ep2 = sc.gen_episode_batch(np.array(["cci"] * 4), 8, rng,
                               load_ratio=0.9, include_iq=False, int_dbm=tr)
    cfg = ControllerConfig(ewma_alpha=1.0, hysteresis_steps=1,
                           fallback_split=0)
    res = simulate_fleet(ep2, stacked, prof, cfg)
    np.testing.assert_array_equal(res.splits[0], res.splits[2])
    np.testing.assert_array_equal(res.splits[1], res.splits[3])
    assert not np.array_equal(res.splits[0], res.splits[1])


def test_tp_clip_single_source():
    """The estimator clamp range is owned by the PSO sweep config; the sim
    engine must import it, not re-declare it (the two stay equal by
    construction)."""
    from repro.channel import throughput as tpm
    from repro.core import pso
    from repro.sim import engine
    import repro.sim as sim
    assert engine.TP_CLIP_MBPS is pso.TP_CLIP_MBPS
    assert sim.TP_CLIP_MBPS is pso.TP_CLIP_MBPS
    # and the range itself matches the sweep: bucket 1 .. the paper's peak
    assert pso.TP_CLIP_MBPS == (1.0, tpm.PEAK_MBPS)


def test_estimate_fleet_shapes_and_clip():
    """Batched estimator inference: (N, T) predictions, clipped into the
    PSO sweep range."""
    jax = pytest.importorskip("jax")
    from repro.estimator.model import EstimatorConfig, init_estimator
    from repro.sim import TP_CLIP_MBPS, estimate_fleet
    rng = np.random.default_rng(8)
    e = EstimatorConfig(n_sc=N_SC_TEST, lstm_hidden=8, hidden=8)
    params = init_estimator(e, jax.random.PRNGKey(0))
    ep = sc.gen_episode_batch(np.array(["none", "jamming"]), 3, rng,
                              n_sc=N_SC_TEST)
    est = estimate_fleet(ep, (e, params))
    assert est.shape == (2, 3)
    assert est.min() >= TP_CLIP_MBPS[0] and est.max() <= TP_CLIP_MBPS[1]


def test_estimate_fleet_vectorized_matches_per_period_loop():
    """The period-chunked forward (many whole report periods flattened
    into one dispatch) must reproduce the old one-forward-per-period loop:
    the estimator is row-wise, so only the batch packing changed."""
    jax = pytest.importorskip("jax")
    from repro.estimator.model import EstimatorConfig, init_estimator
    from repro.estimator.train import predict
    from repro.sim import TP_CLIP_MBPS, estimate_fleet
    from repro.sim.engine import EST_CHUNK_ROWS
    rng = np.random.default_rng(9)
    e = EstimatorConfig(n_sc=N_SC_TEST, lstm_hidden=8, hidden=8)
    params = init_estimator(e, jax.random.PRNGKey(1))
    n, T = 3, 7
    scen = np.asarray(sc.SCENARIOS)[np.arange(n) % 4]
    ep = sc.gen_episode_batch(scen, T, rng, n_sc=N_SC_TEST)
    assert n * T <= EST_CHUNK_ROWS  # whole episode fits one chunk
    est = estimate_fleet(ep, (e, params))
    # reference: the pre-vectorization loop, one forward per period
    wins = ep.kpm_windows(normalize=True).astype(np.float32)
    alloc = ep.alloc_ratio.astype(np.float32)
    ref = np.empty((n, T))
    for t in range(T):
        data = {"kpms": wins[:, t], "iq": ep.iq[:, t].astype(np.float32),
                "alloc": alloc, "tp": np.empty(n, np.float32)}
        ref[:, t] = np.asarray(predict(e, params, data, batch=None))
    ref = np.clip(ref, TP_CLIP_MBPS[0], TP_CLIP_MBPS[1])
    np.testing.assert_allclose(est, ref, rtol=1e-5, atol=1e-5)


def test_split_metrics_zero_throughput_finite():
    """Zero / near-zero throughput (an empty slot, a starved PRB grant)
    must yield huge-but-finite delay, never inf/NaN — and the floor must
    be invisible at any real operating point (>= the 0.01 Mbps PRB
    floor)."""
    from repro.sim import split_metrics
    from repro.sim.engine import TP_FLOOR_BPS
    prof = vgg_split_profile(FULL)
    splits = np.arange(len(prof.data_bytes))[None]
    zero = np.zeros_like(splits, float)
    delay, priv, energy = split_metrics(prof, splits, zero)
    assert np.isfinite(delay).all() and (delay > 0).all()
    assert np.isfinite(priv).all() and np.isfinite(energy).all()
    # the floored delay is exactly the transfer at TP_FLOOR_BPS
    expect = (prof.d_ue(UE_VM_2CORE)[splits] + prof.d_ser(EDGE_A40X2)[splits]
              + prof.data_bytes[splits] * 8.0 / TP_FLOOR_BPS)
    np.testing.assert_array_equal(delay, expect)
    # bit-unchanged for any live throughput: the smallest rate the PRB
    # scheduler can grant (0.01 Mbps = 1e4 bps) is far above the floor
    tp = np.full_like(splits, 0.01, dtype=float)
    d_floor, _, _ = split_metrics(prof, splits, tp)
    ref = (prof.d_ue(UE_VM_2CORE)[splits] + prof.d_ser(EDGE_A40X2)[splits]
           + prof.data_bytes[splits] * 8.0 / (0.01 * 1e6))
    np.testing.assert_array_equal(d_floor, ref)
