"""Every module under src/repro must import.

A missing package (like the repro.dist regression that once broke the
whole suite at collection time) fails here with a precise module list,
instead of as an opaque collection error in some downstream test.
"""
import importlib
import pkgutil

import repro


def test_every_repro_module_imports():
    failures = []

    def record(name):
        failures.append((name, "error during pkgutil walk"))

    names = [m.name for m in pkgutil.walk_packages(repro.__path__,
                                                   prefix="repro.",
                                                   onerror=record)]
    assert names, "walk_packages found nothing — PYTHONPATH broken?"
    for name in names:
        try:
            importlib.import_module(name)
        except Exception as e:  # noqa: BLE001 — report all, not just first
            failures.append((name, repr(e)))
    assert not failures, f"unimportable modules: {failures}"
