"""VGG16 + LM split-inference equivalence tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import boundary
from repro.core.splitting import (lm_split_infer, lm_split_points, vgg_head,
                                  vgg_split_infer, vgg_tail)
from repro.models import init_params
from repro.models.lm import forward
from repro.models.vgg import REDUCED, forward as vgg_forward, init_vgg, layout


def test_vgg_layout_has_43_split_points():
    assert len(layout()) == 43


def test_vgg_forward_shapes():
    key = jax.random.PRNGKey(0)
    params = init_vgg(REDUCED, key)
    x = jax.random.normal(key, (2, REDUCED.image_size, REDUCED.image_size, 3))
    out = vgg_forward(REDUCED, params, x)
    assert out.shape == (2, REDUCED.num_classes)
    acts = vgg_forward(REDUCED, params, x, collect=True)
    assert len(acts) == 43


@pytest.mark.parametrize("l", [1, 5, 17, 31, 34, 40])
def test_vgg_split_equals_full(l):
    key = jax.random.PRNGKey(1)
    params = init_vgg(REDUCED, key)
    x = jax.random.normal(key, (2, REDUCED.image_size, REDUCED.image_size, 3))
    full = vgg_forward(REDUCED, params, x)
    act = vgg_head(REDUCED, params, x, l)
    split = vgg_tail(REDUCED, params, act, l)
    np.testing.assert_allclose(np.asarray(split), np.asarray(full),
                               rtol=1e-4, atol=1e-5)


def test_vgg_split_int8_codec_close():
    key = jax.random.PRNGKey(2)
    params = init_vgg(REDUCED, key)
    x = jax.random.normal(key, (2, REDUCED.image_size, REDUCED.image_size, 3))
    full = vgg_forward(REDUCED, params, x)
    out = vgg_split_infer(REDUCED, params, x, 17, codec=boundary.INT8)
    # probabilities: small drift acceptable
    assert float(jnp.abs(out - full).max()) < 0.05


@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-370m",
                                  "recurrentgemma-2b"])
def test_lm_split_matches_forward(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    ref, _, _ = forward(cfg, params, batch, mode="train", remat="none")
    ref_last = ref[:, -1:]
    ks = lm_split_points(cfg)
    k = ks[len(ks) // 2]
    out = lm_split_infer(cfg, params, batch, k, codec=boundary.FP16)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_last, np.float32),
                               rtol=0.1, atol=0.1)
