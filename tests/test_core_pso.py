"""PSO (Algorithm 1), objective, dCor, codec, controller tests — incl.
property tests pinning the vectorised PSO to the pseudocode (run through
hypothesis when available, otherwise a fixed-seed sweep of the same
checks, so the suite never fails collection on a missing extra)."""
try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boundary
from repro.core.controller import AdaptiveSplitController, ControllerConfig
from repro.core.energy import EDGE_A40X2, UE_VM_2CORE, DeviceProfile
from repro.core.objective import Constraints, Weights, evaluate
from repro.core.privacy import dcor, pairwise_dists
from repro.core.profiles import SplitProfile
from repro.core.pso import (LookupTable, NO_SPLIT, pso_reference,
                            pso_vectorized)
from repro.models.vgg import vgg_split_profile, FULL


def random_profile(rng, L=12):
    flops = np.cumsum(rng.uniform(1e8, 5e9, L))
    data = rng.uniform(1e4, 5e6, L)
    priv = np.clip(np.sort(rng.uniform(0.2, 0.95, L))[::-1], 0, 1)
    return SplitProfile("rand", flops, data, priv,
                        [f"l{i}" for i in range(L)])


def _check_vectorized_matches_reference(seed, tau, rho, emax):
    rng = np.random.default_rng(seed)
    prof = random_profile(rng)
    cons = Constraints(tau_max_s=tau, rho_max=rho, e_max_j=emax)
    w = Weights(w_delay=1.0, w_privacy=0.5, w_energy=0.5)
    ref = pso_reference(prof, UE_VM_2CORE, EDGE_A40X2, w, cons, 60)
    vec = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2, w, cons, 60)
    np.testing.assert_array_equal(ref.table, vec.table)


def _check_tables_respect_constraints(seed):
    rng = np.random.default_rng(seed)
    prof = random_profile(rng)
    cons = Constraints(tau_max_s=1.0, rho_max=0.8, e_max_j=10.0)
    w = Weights(1.0, 0.3, 0.3)
    tab = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2, w, cons, 80)
    terms = evaluate(prof, UE_VM_2CORE, EDGE_A40X2,
                     np.arange(1, 81) * 1e6, w, cons)
    for tp in range(1, 81):
        l = tab.table[tp]
        if l != NO_SPLIT:
            assert terms.feasible[l, tp - 1], (tp, l)


if HAVE_HYPOTHESIS:
    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.given(seed=st.integers(0, 10_000),
                      tau=st.floats(0.05, 3.0),
                      rho=st.floats(0.3, 1.0),
                      emax=st.floats(0.5, 50.0))
    def test_pso_vectorized_matches_reference(seed, tau, rho, emax):
        _check_vectorized_matches_reference(seed, tau, rho, emax)

    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.given(seed=st.integers(0, 10_000))
    def test_pso_tables_respect_constraints(seed):
        _check_tables_respect_constraints(seed)
else:
    @pytest.mark.parametrize("seed,tau,rho,emax", [
        (0, 0.05, 0.3, 0.5), (1, 0.2, 0.5, 2.0), (2, 0.5, 0.8, 10.0),
        (3, 1.0, 0.95, 25.0), (4, 1.7, 1.0, 50.0), (5, 3.0, 0.6, 5.0),
        (6, 0.09, 0.99, 40.0), (7, 2.4, 0.45, 0.9),
    ])
    def test_pso_vectorized_matches_reference(seed, tau, rho, emax):
        _check_vectorized_matches_reference(seed, tau, rho, emax)

    @pytest.mark.parametrize("seed", range(8))
    def test_pso_tables_respect_constraints(seed):
        _check_tables_respect_constraints(seed)


def test_pso_delay_only_matches_bruteforce():
    prof = vgg_split_profile(FULL)
    cons = Constraints()
    w = Weights(1.0, 0.0, 0.0)
    tab = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2, w, cons, 60)
    terms = evaluate(prof, UE_VM_2CORE, EDGE_A40X2,
                     np.arange(1, 61) * 1e6, w, cons)
    brute = np.argmin(terms.d_e2e, axis=0)
    np.testing.assert_array_equal(tab.table[1:], brute)


def test_vgg_profile_pool_layers_shrink_data():
    prof = vgg_split_profile(FULL)
    pools = [i for i, n in enumerate(prof.layer_names) if ":pool" in n]
    for i in pools:
        assert prof.data_bytes[i] < prof.data_bytes[i - 1]
    assert np.all(np.diff(prof.flops_head) >= 0)


def test_deeper_split_higher_tp_shifts_earlier():
    """Fig. 5d trend: as throughput degrades, the delay-optimal split moves
    deeper (transmitting less / later beats transmitting early huge maps)."""
    prof = vgg_split_profile(FULL)
    w = Weights(1.0, 0.0, 0.0)
    cons = Constraints(rho_max=0.98)  # SC semantics: raw input never leaves
    tab = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2, w, cons, 60)
    assert tab.table[60] <= tab.table[15]
    assert tab.table[15] > 1  # degraded link pushes the split deeper


# ------------------------------------------------------------------ dCor
def test_dcor_self_is_one():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
    assert abs(float(dcor(x, x)) - 1.0) < 1e-5


def test_dcor_independent_is_small():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (128, 4))
    y = jax.random.normal(k2, (128, 4))
    assert float(dcor(x, y)) < 0.35


def test_dcor_isometry_invariant():
    k = jax.random.PRNGKey(2)
    x = jax.random.normal(k, (48, 6))
    y = x @ jnp.eye(6)[:, ::-1] + 3.0  # permutation + shift = isometry
    assert abs(float(dcor(x, y)) - 1.0) < 1e-4


def test_pairwise_dists_matches_numpy():
    x = np.random.default_rng(3).normal(size=(20, 5)).astype(np.float32)
    d = np.asarray(pairwise_dists(jnp.asarray(x)))
    ref = np.linalg.norm(x[:, None] - x[None], axis=-1)
    np.testing.assert_allclose(d, ref, atol=1e-4)


# ------------------------------------------------------------------ codec
@pytest.mark.parametrize("codec", [boundary.INT8, boundary.INT4])
def test_codec_roundtrip_error(codec):
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 64)) * 3.0
    y = boundary.roundtrip(x, codec)
    rel = float(jnp.abs(y - x).max() / jnp.abs(x).max())
    assert rel < (0.02 if codec.bits == 8 else 0.2)


def test_codec_transmit_bytes():
    assert boundary.transmit_bytes((4, 16, 128), boundary.INT8) == (
        4 * 16 * 128 + 4 * 4 * 16)
    assert boundary.transmit_bytes((2, 8, 64), boundary.FP16) == 2 * 8 * 64 * 2


# ------------------------------------------------------------------ controller
def test_controller_hysteresis():
    prof = vgg_split_profile(FULL)
    tab = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2,
                         Weights(1.0, 0.0, 0.0), Constraints(rho_max=0.98), 60)
    ctl = AdaptiveSplitController(tab, ControllerConfig(
        ewma_alpha=1.0, hysteresis_steps=2))
    l60 = tab.query(60)
    l5 = tab.query(5)
    assert l60 != l5
    ctl.update(60)
    ctl.update(60)
    assert ctl.current_split == l60
    ctl.update(5)  # single blip: no switch yet
    assert ctl.current_split == l60
    ctl.update(5)
    assert ctl.current_split == l5


def test_lookup_query_low_throughput_not_clamped_to_one():
    """Regression: near-zero throughput must read bucket 0 (NO_SPLIT — the
    integer sweep starts at 1 Mbps), not be promoted to the 1 Mbps entry
    whose TP_min the actual link cannot meet."""
    tab = LookupTable("t", np.array([NO_SPLIT, 4, 4, 7], np.int32),
                      np.zeros(3), np.ones(3, bool))
    assert tab.query(0.2) == NO_SPLIT  # rounds to 0: no feasible split
    assert tab.query(0.6) == 4        # rounds to 1: true bucket
    assert tab.query(2.4) == 4
    assert tab.query(1e9) == 7        # clamped to tp_max at the top end


def test_pso_built_tables_keep_bucket_zero_infeasible():
    rng = np.random.default_rng(0)
    prof = random_profile(rng)
    cons = Constraints(tau_max_s=1.0, rho_max=0.8, e_max_j=10.0)
    tab = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2,
                         Weights(1.0, 0.3, 0.3), cons, 40)
    assert tab.table[0] == NO_SPLIT
    assert tab.query(0.3) == NO_SPLIT
    ref = pso_reference(prof, UE_VM_2CORE, EDGE_A40X2,
                        Weights(1.0, 0.3, 0.3), cons, 40)
    assert ref.table[0] == NO_SPLIT


def test_controller_clears_pending_after_switch_and_revert():
    """Pin the switch trace: a switch or a revert-to-current must clear the
    pending proposal entirely; a stale pending_split must never survive."""
    tab = LookupTable("t", np.array([NO_SPLIT, 3, 3, 5, 5, 5], np.int32),
                      np.zeros(6), np.ones(6, bool))
    ctl = AdaptiveSplitController(tab, ControllerConfig(
        ewma_alpha=1.0, hysteresis_steps=2))
    ctl.update(1)                      # step 0: propose 3 (pending)
    ctl.update(1)                      # step 1: agree -> switch to 3
    assert ctl.current_split == 3
    assert ctl.pending_split is None and ctl.pending_count == 0
    ctl.update(3)                      # step 2: propose 5 (pending)
    assert ctl.pending_split == 5 and ctl.pending_count == 1
    ctl.update(1)                      # step 3: revert to 3 -> clear pending
    assert ctl.pending_split is None and ctl.pending_count == 0
    ctl.update(3)                      # step 4: lone 5 again: fresh count
    assert ctl.current_split == 3 and ctl.pending_count == 1
    ctl.update(3)                      # step 5: agree -> switch to 5
    assert ctl.current_split == 5
    assert [(s, l) for s, _, l in ctl.switches] == [(1, 3), (5, 5)]
