"""Sequence parallelism (ctx -> 'model'): dry-run one train_4k cell with
the override and pin, at lowering level, that the residual-stream carries
actually pick up the model-axis sharding (Megatron-SP style). Closes the
ROADMAP item that shipped the override without ever exercising it."""
import jax
import pytest

from repro.configs import get_config
from repro.dist import sharding as sh
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_cell, lower_cell

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs >= 8 (virtual) devices")

# mesh (data=2, model=4): the ("batch", "ctx", "embed") residual constraint
# resolves to P("data", "model", None), whose HLO tiling is devices=[2,4,1]
SEQ_SHARDED = "devices=[2,4,1]<=[8]"


def _lower_train4k(overrides):
    cfg = get_config("stablelm-1.6b").reduced()
    mesh = make_host_mesh(2, 4)
    assert dict(mesh.shape) == {"data": 2, "model": 4}
    with sh.use_rules(mesh, overrides) as rs:
        cell = build_cell(cfg, "train_4k", rs, remat="none")
        return lower_cell(cell, mesh, overrides)


@multi_device
def test_train4k_ctx_to_model_lowers_sequence_parallel():
    lowered = _lower_train4k({"ctx": "model"})
    text = lowered.as_text()
    assert SEQ_SHARDED in text, (
        "ctx->model override did not shard the residual stream over the "
        "model axis")


@multi_device
def test_train4k_default_keeps_ctx_replicated():
    assert SEQ_SHARDED not in _lower_train4k(None).as_text()
