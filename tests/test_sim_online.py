"""Closed-loop online estimator adaptation (repro.sim.online).

Pins the four load-bearing properties of the subsystem: (1) replay-buffer
ring semantics (wrap, overwrite-oldest, batch > capacity), (2)
drift-trigger hysteresis (calibration never fires; patience and cooldown
gate triggers), (3) the sharded and unsharded adaptation steps are
numerically interchangeable (data-sharded batch + psum'd grads == single
device), and (4) ``simulate_fleet(online=None)`` is bit-identical to the
PR 4 engine program.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel import scenarios as sc
from repro.core.controller import ControllerConfig
from repro.core.pso import LookupTable
from repro.estimator.model import EstimatorConfig, init_estimator
from repro.estimator.train import make_indexed_step
from repro.models.vgg import FULL, vgg_split_profile
from repro.optim import AdamW
from repro.sim import (DriftConfig, OnlineConfig, buffer_add, buffer_count,
                       buffer_data, buffer_init, drift_init, drift_step,
                       drift_threshold, emit_period_samples, estimate_fleet,
                       make_serving_mesh, online_estimate_fleet,
                       run_controllers, simulate_fleet, split_metrics)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs >= 8 (virtual) devices")

N_SC_TEST = 16


def tiny_estimator(seed: int = 0):
    e = EstimatorConfig(n_sc=N_SC_TEST, lstm_hidden=8, hidden=8)
    return e, init_estimator(e, jax.random.PRNGKey(seed))


def episode(n: int, T: int = 6, seed: int = 5):
    rng = np.random.default_rng(seed)
    names = np.asarray(sc.SCENARIOS)[np.arange(n) % len(sc.SCENARIOS)]
    return sc.gen_episode_batch(names, T, rng, n_sc=N_SC_TEST)


def fig6_style_table(prof):
    return LookupTable(ue_name="t", table=np.full(41, 3, np.int32),
                       tp_min_mbps=np.zeros(len(prof.data_bytes)),
                       feasible_prefilter=np.ones(len(prof.data_bytes),
                                                  bool))


# ----------------------------------------------------------------- buffer
def test_buffer_ring_semantics():
    """Wrap-around overwrites the OLDEST rows; count saturates at cap."""
    e, _ = tiny_estimator()
    buf = buffer_init(8, e)
    assert buf.capacity == 8 and buffer_count(buf) == 0

    def rows(lo, n):
        tp = np.arange(lo, lo + n, dtype=np.float32)
        kpms = np.tile(tp[:, None, None], (1, e.window, e.n_kpms))
        iq = np.tile(tp[:, None, None, None], (1, 2, e.n_sc, e.n_sym))
        return kpms, iq, tp * 0.01, tp

    buf = buffer_add(buf, *rows(0, 5))
    assert buffer_count(buf) == 5 and int(buf.head) == 5
    np.testing.assert_array_equal(np.asarray(buf.tp[:5]), np.arange(5))
    # 5 more: slots 5..7 then wrap to 0..1 — rows 0 and 1 (oldest) die
    buf = buffer_add(buf, *rows(5, 5))
    assert buffer_count(buf) == 8 and int(buf.head) == 2
    np.testing.assert_array_equal(
        np.asarray(buf.tp), [8, 9, 2, 3, 4, 5, 6, 7])
    # every field moves together (same ring positions)
    np.testing.assert_array_equal(np.asarray(buf.kpms[:, 0, 0]),
                                  np.asarray(buf.tp))
    np.testing.assert_allclose(np.asarray(buf.alloc),
                               np.asarray(buf.tp) * 0.01, rtol=1e-6)


def test_buffer_add_larger_than_capacity_keeps_newest():
    """A batch > capacity keeps exactly the newest ``capacity`` rows (the
    scatter must never see duplicate indices)."""
    e, _ = tiny_estimator()
    buf = buffer_init(4, e)
    kpms = np.zeros((10, e.window, e.n_kpms), np.float32)
    iq = np.zeros((10, 2, e.n_sc, e.n_sym), np.float32)
    buf = buffer_add(buf, kpms, iq, np.zeros(10, np.float32),
                     np.arange(10, dtype=np.float32))
    assert buffer_count(buf) == 4
    assert sorted(np.asarray(buf.tp).tolist()) == [6, 7, 8, 9]
    data = buffer_data(buf)
    assert set(data) == {"kpms", "iq", "alloc", "tp"}


@multi_device
def test_buffer_sharded_over_data_axis():
    """Under a serving mesh the buffer's row axis is committed on the
    mesh's data axis (the batch rule), not replicated."""
    from jax.sharding import PartitionSpec as P
    e, _ = tiny_estimator()
    serving = make_serving_mesh("8x1")
    buf = buffer_init(16, e, serving=serving)
    assert buf.iq.sharding.spec == P("data", None, None, None)
    assert buf.kpms.sharding.spec == P("data", None, None)
    assert buf.tp.sharding.spec == P("data")


# ---------------------------------------------------------- drift monitor
def test_drift_calibration_never_fires_and_sets_baseline():
    cfg = DriftConfig(calibrate_periods=4, ratio=1.5, patience=1, cooldown=0)
    st = drift_init()
    for r in (10.0, 12.0, 8.0, 10.0):  # huge values: would fire if armed
        st, fired = drift_step(cfg, st, r)
        assert not fired
    assert st.baseline == pytest.approx(10.0)
    assert drift_threshold(cfg, st) == pytest.approx(15.0)


def test_drift_trigger_hysteresis():
    """patience gates the trigger: one noisy period is not drift; a
    sustained exceedance fires exactly once, then cooldown disarms."""
    cfg = DriftConfig(alpha=1.0, calibrate_periods=2, ratio=1.5,
                      patience=2, cooldown=3)
    st = drift_init()
    for r in (10.0, 10.0):  # calibrate: baseline 10, threshold 15
        st, fired = drift_step(cfg, st, r)
    # a single spike (patience=2) must NOT fire
    st, fired = drift_step(cfg, st, 40.0)
    assert not fired and st.above == 1
    st, fired = drift_step(cfg, st, 12.0)  # back below: streak resets
    assert not fired and st.above == 0
    # sustained exceedance: fires on the 2nd consecutive period
    st, fired = drift_step(cfg, st, 40.0)
    assert not fired
    st, fired = drift_step(cfg, st, 40.0)
    assert fired and st.n_triggers == 1 and st.cooldown_left == 3
    # cooldown: still way above threshold, but disarmed for 3 periods
    for _ in range(3):
        st, fired = drift_step(cfg, st, 40.0)
        assert not fired
    # re-armed: the streak must build up again (patience from zero)
    st, fired = drift_step(cfg, st, 40.0)
    assert not fired
    st, fired = drift_step(cfg, st, 40.0)
    assert fired and st.n_triggers == 2


def test_drift_unarmed_holds_streak_without_consuming_trigger():
    """armed=False (buffer below min_fill) must not swallow a trigger:
    the streak holds at patience — no cooldown, no n_triggers — and the
    first armed period fires immediately."""
    cfg = DriftConfig(alpha=1.0, calibrate_periods=1, ratio=1.5,
                      patience=2, cooldown=3)
    st = drift_init()
    st, _ = drift_step(cfg, st, 10.0)  # calibrate: threshold 15
    for _ in range(4):  # sustained drift, but the caller can't act yet
        st, fired = drift_step(cfg, st, 40.0, armed=False)
        assert not fired
    assert st.above == cfg.patience and st.n_triggers == 0
    assert st.cooldown_left == 0
    st, fired = drift_step(cfg, st, 40.0, armed=True)
    assert fired and st.n_triggers == 1  # acts the moment it can


def test_online_min_fill_defers_first_burst():
    """A trigger raised while the buffer is under min_fill is deferred,
    not lost: the burst runs on the first period the buffer is ready,
    and checkpoint steps stay 1..n_adaptations."""
    e, params = tiny_estimator()
    ep = episode(4, T=10)  # 4 rows/period: min_fill=16 ready at t=3
    ocfg = OnlineConfig(capacity=64, batch=8, steps=2, min_fill=16,
                        drift=DriftConfig(calibrate_periods=1,
                                          threshold_mbps=0.0, patience=1,
                                          cooldown=99))
    est, stats = online_estimate_fleet(ep, (e, params), ocfg)
    # patience satisfied from t=1 on, but fill(t)=4(t+1): first armed
    # period is t=3 — exactly one burst (cooldown then covers the rest)
    assert stats.n_adaptations == 1
    np.testing.assert_array_equal(np.nonzero(stats.adapted)[0], [3])
    assert stats.ckpt_steps == []


def test_drift_absolute_threshold_override():
    cfg = DriftConfig(calibrate_periods=1, threshold_mbps=5.0, patience=1,
                      cooldown=0)
    st = drift_init()
    st, fired = drift_step(cfg, st, 100.0)  # calibration period
    assert not fired
    st, fired = drift_step(cfg, st, 6.0)
    assert fired  # 6 > 5 regardless of the (huge) calibrated baseline


# ------------------------------------------------- sharded vs unsharded
@multi_device
def test_sharded_vs_unsharded_step_allclose():
    """One adaptation step under the serving mesh (data-sharded batch,
    replicated params, psum'd grads) == the single-device step: same loss,
    same updated params to float tolerance."""
    e, params = tiny_estimator()
    serving = make_serving_mesh("8x1")
    opt = AdamW(lr=1e-3, weight_decay=1e-4, clip_norm=1.0)
    rng = np.random.default_rng(1)
    data = {"kpms": jnp.asarray(rng.normal(size=(32, e.window, e.n_kpms)),
                                jnp.float32),
            "iq": jnp.asarray(rng.normal(size=(32, 2, e.n_sc, e.n_sym)),
                              jnp.float32),
            "alloc": jnp.asarray(rng.uniform(size=32), jnp.float32),
            "tp": jnp.asarray(rng.uniform(10, 100, 32), jnp.float32)}
    idx = jnp.asarray(rng.integers(0, 32, 16), jnp.int32)
    key = jax.random.PRNGKey(7)
    plain = make_indexed_step(e, opt)
    shard = make_indexed_step(e, opt, mesh=serving.mesh,
                              overrides=serving.rule_overrides())
    p0, _, l0 = plain(params, opt.init(params), data, idx, key)
    p1, _, l1 = shard(params, opt.init(params), data, idx, key)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@multi_device
def test_online_sharded_matches_unsharded_loop():
    """The whole closed loop under a serving mesh tracks the unsharded
    loop: same estimates (allclose) and the same adaptation schedule."""
    e, params = tiny_estimator()
    ep = episode(8, T=6)
    ocfg = OnlineConfig(capacity=64, batch=16, steps=3, min_fill=8,
                        drift=DriftConfig(calibrate_periods=2,
                                          threshold_mbps=0.0, patience=1,
                                          cooldown=1))
    est_u, st_u = online_estimate_fleet(ep, (e, params), ocfg)
    est_s, st_s = online_estimate_fleet(ep, (e, params), ocfg,
                                        serving=make_serving_mesh("8x1"))
    np.testing.assert_allclose(est_s, est_u, rtol=1e-4, atol=1e-3)
    np.testing.assert_array_equal(st_s.adapted, st_u.adapted)
    assert st_s.n_adaptations == st_u.n_adaptations > 0


# --------------------------------------------------- engine bit-identity
def test_online_none_is_bit_identical_to_pr4_program():
    """simulate_fleet(online=None) must BE the PR 4 program: the same
    estimates, splits and metrics as the manual estimate_fleet ->
    run_controllers -> split_metrics composition, bit for bit."""
    e, params = tiny_estimator()
    ep = episode(8, T=5)
    prof = vgg_split_profile(FULL)
    table = fig6_style_table(prof)
    cfg = ControllerConfig(0.5, 2, 3)
    res = simulate_fleet(ep, table, prof, cfg, estimator=(e, params),
                         online=None)
    # the PR 4 composition, spelled out
    est = estimate_fleet(ep, (e, params))
    tables = np.broadcast_to(table.table, (ep.n_ues, len(table.table)))
    splits = run_controllers(tables, est, cfg, cfg.fallback_split)
    delay, priv, energy = split_metrics(prof, splits,
                                        np.asarray(ep.tp_mbps, float))
    np.testing.assert_array_equal(res.est_tp, est)
    np.testing.assert_array_equal(res.splits, splits)
    np.testing.assert_array_equal(res.delay_s, delay)
    np.testing.assert_array_equal(res.privacy, priv)
    np.testing.assert_array_equal(res.energy_j, energy)
    assert res.online is None
    # and the kwarg default is the same code path
    res2 = simulate_fleet(ep, table, prof, cfg, estimator=(e, params))
    np.testing.assert_array_equal(res2.splits, res.splits)
    np.testing.assert_array_equal(res2.est_tp, res.est_tp)


def test_emit_period_samples_matches_episode():
    ep = episode(4, T=5)
    wins = ep.kpm_windows(normalize=True).astype(np.float32)
    s = emit_period_samples(ep, 3)
    np.testing.assert_array_equal(s["kpms"], wins[:, 3])
    np.testing.assert_array_equal(s["iq"], ep.iq[:, 3].astype(np.float32))
    np.testing.assert_array_equal(s["alloc"],
                                  ep.alloc_ratio.astype(np.float32))
    np.testing.assert_array_equal(s["tp"],
                                  ep.tp_mbps[:, 3].astype(np.float32))


# ------------------------------------------------------- adaptation loop
def test_online_adapts_reduces_rmse_and_checkpoints(tmp_path):
    """The closed loop actually learns: with a forced trigger cadence the
    adapted estimator's late-episode RMSE beats the frozen estimator's,
    loss falls across bursts, and every burst lands a checkpoint."""
    e, params = tiny_estimator()
    ep = episode(16, T=16, seed=9)
    ocfg = OnlineConfig(capacity=256, batch=64, steps=10, lr=3e-3,
                        min_fill=16, seed=1,
                        drift=DriftConfig(calibrate_periods=2,
                                          threshold_mbps=0.0, patience=1,
                                          cooldown=1),
                        ckpt_dir=str(tmp_path / "online_ckpt"),
                        ckpt_keep=2)
    frozen = estimate_fleet(ep, (e, params))
    est, stats = online_estimate_fleet(ep, (e, params), ocfg)
    assert stats.n_adaptations >= 3
    assert stats.train_steps == stats.n_adaptations * ocfg.steps
    # the last bursts must fit better than the first
    assert stats.train_loss[-1] < stats.train_loss[0]
    # late-episode RMSE: adapted beats frozen (random-init params are far
    # off; a few bursts on live labels must close most of the gap)
    tp = np.asarray(ep.tp_mbps, float)
    late = slice(ep.n_steps // 2, None)
    rmse_onl = float(np.sqrt(np.mean((est[:, late] - tp[:, late]) ** 2)))
    rmse_frz = float(np.sqrt(np.mean((frozen[:, late] - tp[:, late]) ** 2)))
    assert rmse_onl < rmse_frz
    # checkpoints: one per burst, pruned to ckpt_keep, restorable
    from repro.checkpoint import CheckpointManager
    assert stats.ckpt_steps == list(range(1, stats.n_adaptations + 1))
    mgr = CheckpointManager(ocfg.ckpt_dir, keep=ocfg.ckpt_keep)
    assert mgr.latest() == stats.n_adaptations
    restored, step = mgr.restore(params)
    assert step == stats.n_adaptations
    for a, b in zip(jax.tree.leaves(restored),
                    jax.tree.leaves(stats.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_online_no_trigger_means_frozen_estimates():
    """With the monitor never tripping (huge absolute threshold) the loop
    degenerates to the frozen per-period predict: estimates equal
    estimate_fleet's and no train step runs."""
    e, params = tiny_estimator()
    ep = episode(4, T=4)
    ocfg = OnlineConfig(capacity=32, batch=8, steps=2, min_fill=4,
                        drift=DriftConfig(calibrate_periods=1,
                                          threshold_mbps=1e9, patience=1))
    est, stats = online_estimate_fleet(ep, (e, params), ocfg)
    np.testing.assert_allclose(est, estimate_fleet(ep, (e, params)),
                               rtol=1e-6, atol=1e-6)
    assert stats.n_adaptations == 0 and stats.train_steps == 0
    assert stats.ckpt_steps == []


def test_simulate_fleet_online_hook():
    """The engine hook returns a FleetResult whose controllers consumed
    the adapted estimates, with the adaptation trace attached."""
    e, params = tiny_estimator()
    ep = episode(8, T=8)
    prof = vgg_split_profile(FULL)
    table = fig6_style_table(prof)
    cfg = ControllerConfig(0.5, 2, 3)
    ocfg = OnlineConfig(capacity=64, batch=16, steps=4, min_fill=8,
                        drift=DriftConfig(calibrate_periods=2,
                                          threshold_mbps=0.0, patience=1,
                                          cooldown=1))
    res = simulate_fleet(ep, table, prof, cfg, estimator=(e, params),
                         online=ocfg, fixed_split=3)
    assert res.online is not None and res.online.n_adaptations > 0
    assert res.online.rmse.shape == (ep.n_steps,)
    # the splits are the controller scan over the adapted estimates
    tables = np.broadcast_to(table.table, (ep.n_ues, len(table.table)))
    np.testing.assert_array_equal(
        res.splits, run_controllers(tables, res.est_tp, cfg, 3))
    # ValueError, not assert: the guard must survive python -O
    with pytest.raises(ValueError, match="needs an estimator"):
        simulate_fleet(ep, table, prof, cfg, online=ocfg)


def test_online_config_frozen_and_hashable():
    """OnlineConfig/DriftConfig key lru caches (the step-program cache):
    they must stay frozen and hashable."""
    a = OnlineConfig()
    b = dataclasses.replace(a, steps=7)
    assert hash(a) != () and a != b
    assert hash(DriftConfig()) == hash(DriftConfig())
