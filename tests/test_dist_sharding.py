"""repro.dist.sharding: ruleset resolution, override precedence, template
shardings for serving, and the no-mesh fallback contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist import sharding as sh
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import serve_overrides, serve_param_template
from repro.models import template as T

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs >= 8 (virtual) devices")


def host_mesh():
    mesh = make_host_mesh(2, 4)
    assert dict(mesh.shape) == {"data": 2, "model": 4}
    return mesh


# ------------------------------------------------------------------ no mesh
def test_no_mesh_fallback_is_identity():
    assert sh.active() is None
    x = jnp.ones((4, 8))
    assert sh.constrain(x, ("batch", "embed")) is x
    assert sh.axis_size("model") == 1
    assert sh.axis_size("data") == 1
    assert sh.kv_repeat(2, 8) == 1


def test_single_device_mesh_constrain_is_identity():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jnp.ones((4, 8))
    with sh.use_rules(mesh):
        assert sh.constrain(x, ("batch", "ff")) is x


def test_use_rules_nests_and_restores():
    mesh = make_host_mesh(1, 1)
    with sh.use_rules(mesh) as outer:
        assert sh.active() is outer
        with sh.use_rules(mesh, {"ctx": "model"}) as inner:
            assert sh.active() is inner
            assert inner.rules["ctx"] == "model"
        assert sh.active() is outer
        assert outer.rules["ctx"] is None
    assert sh.active() is None


# ------------------------------------------------------------------ resolution
@multi_device
def test_spec_resolution_and_divisibility():
    with sh.use_rules(host_mesh()) as rs:
        # batch -> ("pod","data"); pod absent from a host mesh -> data only
        assert rs.spec(("batch", "seq", "embed"), (8, 16, 32)) == P(
            "data", None, None)
        # indivisible dim falls back to replicated, not an XLA error
        assert rs.spec(("heads", None), (6, 4)) == P(None, None)
        assert rs.spec(("heads", None), (8, 4)) == P("model", None)
        # first dim claiming a mesh axis wins; duplicates drop
        assert rs.spec(("ff", "heads"), (8, 8)) == P("model", None)
        # experts maps to an "expert" axis no current mesh carries
        assert rs.spec(("experts", "fsdp", "ff"), (8, 8, 8)) == P(
            None, "data", "model")
        assert rs.axis_size("model") == 4
        assert rs.axis_size("data") == 2
        assert rs.axis_size("pod") == 1
        assert rs.axis_size("batch") == 2


@multi_device
def test_override_precedence():
    mesh = host_mesh()
    with sh.use_rules(mesh, {"fsdp": None, "cache_seq": "model"}) as rs:
        # fsdp replicated by override (serving weight replication)
        assert rs.spec(("fsdp", "ff"), (8, 8)) == P(None, "model")
        # cache_seq claims "model" first; kv then drops as a duplicate
        assert rs.spec(("batch", "cache_seq", "kv", None),
                       (8, 32, 4, 64)) == P("data", "model", None, None)
    # defaults untouched after exit
    with sh.use_rules(mesh) as rs:
        assert rs.spec(("fsdp", "ff"), (8, 8)) == P("data", "model")


def test_unknown_logical_axis_raises():
    with sh.use_rules(make_host_mesh(1, 1)) as rs:
        with pytest.raises(KeyError, match="unknown logical axis"):
            rs.spec(("not_an_axis",), (8,))
    with pytest.raises(TypeError):
        sh.Ruleset(make_host_mesh(1, 1), dict(sh.DEFAULT_RULES)).\
            with_overrides({"ff": 3})


# ------------------------------------------------------------------ kv_repeat
@multi_device
def test_kv_repeat_accounts_for_model_sharding():
    with sh.use_rules(host_mesh()):  # model = 4
        assert sh.kv_repeat(4, 8) == 1   # kv already divisible by 4
        assert sh.kv_repeat(2, 8) == 2   # repeat to lcm(2,4)=4 kv heads
        assert sh.kv_repeat(1, 8) == 4   # MQA: one kv head per shard
        assert sh.kv_repeat(3, 6) == 1   # heads (6) can't shard over 4
        assert sh.kv_repeat(1, 2) == 1   # lcm(1,4)=4 > n_heads: stay GQA


# ------------------------------------------------------------------ constrain
@multi_device
def test_constrain_applies_named_sharding_under_jit():
    mesh = host_mesh()
    x = jnp.zeros((8, 16, 32))
    with sh.use_rules(mesh):
        y = jax.jit(lambda t: sh.constrain(t, ("batch", "seq", "embed")))(x)
    want = NamedSharding(mesh, P("data", None, None))
    assert y.sharding.is_equivalent_to(want, x.ndim)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


@multi_device
def test_constrain_all_replicated_is_identity_trace():
    mesh = host_mesh()
    x = jnp.zeros((3, 5))  # nothing divides: spec fully replicated
    with sh.use_rules(mesh):
        y = sh.constrain(x, ("heads", "ff"))
    assert y is x


# ------------------------------------------------------------------ serving
@pytest.mark.parametrize("arch", ["granite-8b", "mixtral-8x22b"])
@multi_device
def test_serve_param_template_shardings(arch):
    """Acceptance: use_rules(make_host_mesh(), serve_overrides(cfg)) yields
    valid NamedShardings for the whole serve param template."""
    cfg = get_config(arch)
    mesh = host_mesh()
    tmpl = serve_param_template(cfg)
    with sh.use_rules(mesh, serve_overrides(cfg)) as rs:
        shd = T.shardings_from_template(tmpl, rs)
    leaves = jax.tree.leaves(shd)
    assert leaves and all(isinstance(s, NamedSharding) for s in leaves)
    # shard_shape() validates every spec against its actual leaf shape
    shard_shapes = jax.tree.map(lambda spec, s: s.shard_shape(spec.shape),
                                tmpl, shd, is_leaf=T.is_spec)
    assert jax.tree.leaves(shard_shapes)


@multi_device
def test_specs_from_template_requires_ruleset():
    cfg = get_config("granite-8b").reduced()
    tmpl = serve_param_template(cfg)
    with pytest.raises(AssertionError):
        T.specs_from_template(tmpl)  # no active ruleset, none passed


# ------------------------------------------------------------------ host mesh
def test_make_host_mesh_clamps_to_device_count():
    n = len(jax.devices())
    mesh = make_host_mesh(16, 16)
    assert mesh.devices.size <= n
    mesh = make_host_mesh(0, 0)  # degenerate request -> (1, 1)
    assert dict(mesh.shape) == {"data": 1, "model": 1}


@multi_device
def test_make_host_mesh_walks_down_to_divisors():
    mesh = make_host_mesh(3, 5)  # 3 does not divide 8 -> data=2, model=4
    assert dict(mesh.shape) == {"data": 2, "model": 4}
