"""Report-period fusion kernels (PR 7): Pallas (interpret=True on CPU)
vs jnp oracles for featurize / lstm / qmm / segsum, plus the contracts
the sim layer leans on — host-path equality for the featurize windows,
``lstm_branch`` equivalence for the LSTM scan, exact integer accumulation
for the int8 matmuls, and ``jax.ops.segment_*`` semantics (masks, empty
segments, dummy-id redirect) for the segment reductions. Property cases
run through hypothesis when available, otherwise a fixed-seed sweep of
the same checks (the suite's standard pattern)."""
try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel import kpm as kpmmod
from repro.channel import scenarios as sc
from repro.estimator.model import (EstimatorConfig, init_estimator,
                                   lstm_branch)
from repro.kernels.featurize import featurize_ref, kpm_feature_windows
from repro.kernels.lstm import (lstm_hidden, lstm_hidden_q, lstm_scan_q_ref,
                                lstm_scan_ref)
from repro.kernels.qmm import int8_matmul, qmm_ref, quantize_weight
from repro.kernels.quant import quantize_ref
from repro.kernels.segsum import segment_reduce

F32 = jnp.float32


def _kpm_trace(n, length, seed=0):
    """Raw-KPM-scaled trace: values in the real columns' dynamic range so
    the fixed normalize affine is exercised away from zero."""
    rng = np.random.default_rng(seed)
    return (np.asarray(kpmmod.KPM_CENTER)
            + np.asarray(kpmmod.KPM_SCALE)
            * rng.normal(size=(n, length, 15))).astype(np.float64)


# ------------------------------------------------------------- featurize
@pytest.mark.parametrize("n,length", [(4, 40), (7, 31), (130, 36)])
def test_featurize_kernel_matches_ref(n, length):
    """Kernel vs oracle over block-unaligned shapes (both dims padded)."""
    x = jnp.asarray(_kpm_trace(n, length), F32)
    c = jnp.asarray(kpmmod.KPM_CENTER, F32)
    s = jnp.asarray(kpmmod.KPM_SCALE, F32)
    got = kpm_feature_windows(x, c, s, 30)
    ref = kpm_feature_windows(x, c, s, 30, use_kernel=False)
    assert got.shape == (n, length - 29, 30, 15)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_featurize_matches_episode_windows():
    """The device path reproduces ``EpisodeBatch.kpm_windows`` — the host
    stride-trick program the fused engine path replaces."""
    rng = np.random.default_rng(3)
    ep = sc.gen_episode_batch(["none", "cci"], 5, rng, n_sc=16)
    wins = ep.kpm_windows(normalize=True).astype(np.float32)
    got = kpm_feature_windows(jnp.asarray(ep.kpms, F32),
                              jnp.asarray(kpmmod.KPM_CENTER),
                              jnp.asarray(kpmmod.KPM_SCALE), sc.WINDOW)
    # window t covers trace steps [t, t + WINDOW) — same convention
    np.testing.assert_allclose(np.asarray(got[:, :ep.n_steps]), wins,
                               rtol=1e-5, atol=1e-5)


def test_featurize_rejects_short_trace():
    x = jnp.zeros((2, 10, 15), F32)
    c = s = jnp.ones((15,), F32)
    with pytest.raises(ValueError, match="holds no"):
        kpm_feature_windows(x, c, s, 30)


if HAVE_HYPOTHESIS:
    @hypothesis.settings(max_examples=10, deadline=None)
    @hypothesis.given(n=st.integers(1, 40), extra=st.integers(0, 25),
                      window=st.integers(2, 12), seed=st.integers(0, 999))
    def test_featurize_shapes_property(n, extra, window, seed):
        x = jnp.asarray(_kpm_trace(n, window + extra, seed), F32)
        c = jnp.asarray(kpmmod.KPM_CENTER, F32)
        s = jnp.asarray(kpmmod.KPM_SCALE, F32)
        got = kpm_feature_windows(x, c, s, window)
        ref = featurize_ref(x, c, s, window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
else:  # pragma: no cover - depends on environment
    @pytest.mark.parametrize("n,extra,window,seed",
                             [(1, 0, 2, 0), (17, 13, 7, 1), (40, 25, 12, 2)])
    def test_featurize_shapes_property(n, extra, window, seed):
        x = jnp.asarray(_kpm_trace(n, window + extra, seed), F32)
        c = jnp.asarray(kpmmod.KPM_CENTER, F32)
        s = jnp.asarray(kpmmod.KPM_SCALE, F32)
        got = kpm_feature_windows(x, c, s, window)
        ref = featurize_ref(x, c, s, window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------------ lstm
def _lstm_params(k, h, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, kh = jax.random.split(key)
    wx = jax.random.normal(kx, (k, 4 * h), F32) * 0.3
    wh = jax.random.normal(kh, (h, 4 * h), F32) * 0.3
    b = jnp.linspace(-0.5, 0.5, 4 * h, dtype=F32)
    return wx, wh, b


@pytest.mark.parametrize("bt,h", [((3, 30), 8), ((65, 12), 24), ((16, 7), 31)])
def test_lstm_kernel_matches_ref(bt, h):
    b_, t = bt
    wx, wh, b = _lstm_params(15, h)
    kpms = jax.random.normal(jax.random.PRNGKey(7), (b_, t, 15), F32)
    got = lstm_hidden(kpms, wx, wh, b)
    ref = lstm_hidden(kpms, wx, wh, b, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_lstm_matches_estimator_branch():
    """``lstm_hidden(...) @ proj`` IS the estimator's temporal branch."""
    e = EstimatorConfig(n_sc=16, lstm_hidden=8, hidden=8)
    params = init_estimator(e, jax.random.PRNGKey(0))["lstm"]
    kpms = jax.random.normal(jax.random.PRNGKey(1), (5, e.window, 15), F32)
    got = lstm_hidden(kpms, params["wx"], params["wh"], params["b"])
    np.testing.assert_allclose(np.asarray(got @ params["proj"]),
                               np.asarray(lstm_branch(params, kpms)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b_,h", [(3, 8), (33, 16)])
def test_lstm_int8_kernel_exact_vs_ref(b_, h):
    """int8 scan: integer accumulation is exact, so kernel == oracle
    bit-for-bit (same order of the same float ops around exact dots)."""
    wx, wh, b = _lstm_params(15, h, seed=2)
    wxq, wxs = quantize_weight(wx, use_kernel=False)
    whq, whs = quantize_weight(wh, use_kernel=False)
    kpms = jax.random.normal(jax.random.PRNGKey(3), (b_, 30, 15), F32)
    got = lstm_hidden_q(kpms, wxq, wxs, whq, whs, b)
    ref = lstm_scan_q_ref(kpms, wxq, wxs, whq, whs, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_lstm_int8_close_to_fp32():
    """Quantization noise stays small on well-scaled weights."""
    wx, wh, b = _lstm_params(15, 16, seed=4)
    wxq, wxs = quantize_weight(wx, use_kernel=False)
    whq, whs = quantize_weight(wh, use_kernel=False)
    kpms = jax.random.normal(jax.random.PRNGKey(5), (8, 30, 15), F32)
    q = lstm_hidden_q(kpms, wxq, wxs, whq, whs, b, use_kernel=False)
    f = lstm_scan_ref(kpms, wx, wh, b)
    assert float(jnp.abs(q - f).max()) < 0.15


# ------------------------------------------------------------------- qmm
@pytest.mark.parametrize("m,k,n", [(8, 15, 32), (100, 33, 17), (257, 64, 96)])
def test_int8_matmul_kernel_exact_vs_ref(m, k, n):
    km, kw = jax.random.split(jax.random.PRNGKey(11))
    x = jax.random.normal(km, (m, k), F32)
    w = jax.random.normal(kw, (k, n), F32) * 0.2
    wq, sw = quantize_weight(w, use_kernel=False)
    got = int8_matmul(x, wq, sw)
    ref = int8_matmul(x, wq, sw, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # and the oracle is literally qmm_ref on the quantized operands
    xq, sx = quantize_ref(x)
    np.testing.assert_array_equal(np.asarray(ref),
                                  np.asarray(qmm_ref(xq, sx, wq, sw)))


def test_int8_matmul_close_to_fp32():
    km, kw = jax.random.split(jax.random.PRNGKey(13))
    x = jax.random.normal(km, (64, 48), F32)
    w = jax.random.normal(kw, (48, 24), F32) * 0.1
    wq, sw = quantize_weight(w, use_kernel=False)
    err = np.abs(np.asarray(int8_matmul(x, wq, sw)) - np.asarray(x @ w))
    assert float(err.max()) < 0.05


# ---------------------------------------------------------------- segsum
def _seg_case(t, n, c, seed, with_mask):
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(t, n)).astype(np.float32)
    g = rng.integers(0, c, (t, n)).astype(np.int32)
    m = rng.random((t, n)) < 0.7 if with_mask else None
    return v, g, m


@pytest.mark.parametrize("op", ["sum", "max"])
@pytest.mark.parametrize("t,n,c,with_mask",
                         [(1, 16, 3, False), (5, 200, 7, True),
                          (12, 1000, 5, True), (3, 33, 1, False)])
def test_segment_reduce_matches_jax_ops(op, t, n, c, with_mask):
    v, g, m = _seg_case(t, n, c, 0, with_mask)
    got = segment_reduce(v, g, c, op=op, mask=m)
    fn = jax.ops.segment_sum if op == "sum" else jax.ops.segment_max
    gm = np.where(m, g, c) if m is not None else g
    ref = np.stack([np.asarray(fn(jnp.asarray(v[i]), jnp.asarray(gm[i]),
                                  num_segments=c + 1))[:c]
                    for i in range(t)])
    # tiled vs scatter accumulation order differs -> f32 rounding noise
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(segment_reduce(v, g, c, op=op, mask=m,
                                  use_kernel=False)), ref,
        rtol=1e-5, atol=1e-5)


def test_segment_reduce_1d_and_broadcast_ids():
    """1-D inputs and (N,) ids under (T, N) values both round-trip."""
    v1 = np.arange(6, dtype=np.float32)
    g1 = np.array([0, 1, 0, 2, 1, 0], np.int32)
    np.testing.assert_allclose(
        np.asarray(segment_reduce(v1, g1, 3)), [v1[[0, 2, 5]].sum(),
                                                v1[[1, 4]].sum(), v1[3]])
    v2 = np.stack([v1, v1 * 2])
    got = segment_reduce(v2, g1, 3)  # ids broadcast over the batch dim
    np.testing.assert_allclose(np.asarray(got)[1], np.asarray(got)[0] * 2)


@pytest.mark.parametrize("op,identity", [("sum", 0.0), ("max", -np.inf)])
def test_segment_reduce_empty_segments(op, identity):
    """Untouched buckets take the op identity — jax.ops semantics, which
    ``scheduler_step``'s empty-cell handling depends on."""
    v = np.ones((2, 4), np.float32)
    g = np.zeros((2, 4), np.int32)
    out = np.asarray(segment_reduce(v, g, 3, op=op))
    assert (out[:, 1:] == identity).all()


def test_cell_load_and_coupling_kernel_match_host():
    """``sim.cells`` consumers: the segsum-kernel aggregation reproduces
    the host one-hot program for per-cell load and the (C, C)-coupled
    interference floor."""
    from repro.sim.cells import cell_load, coupled_interference_mw, \
        ring_coupling
    rng = np.random.default_rng(5)
    n, t, c = 40, 9, 4
    grid = rng.integers(0, c, (n, t))
    demand = rng.uniform(0.05, 1.0, n)
    np.testing.assert_allclose(
        cell_load(grid, demand, c, use_kernel=True),
        cell_load(grid, demand, c), rtol=1e-6, atol=1e-7)
    coup = ring_coupling(c)
    np.testing.assert_allclose(
        coupled_interference_mw(grid, demand, coup, use_kernel=True),
        coupled_interference_mw(grid, demand, coup), rtol=1e-6, atol=1e-9)
    # a cell with no attached UEs reports zero load, not NaN
    grid0 = np.zeros((n, t), np.int64)
    load = cell_load(grid0, demand, 3, use_kernel=True)
    assert np.isfinite(load).all() and (load[1:] == 0).all()


def test_segment_reduce_mask_none_vs_all_true():
    v, g, _ = _seg_case(4, 50, 6, 1, False)
    a = segment_reduce(v, g, 6)
    b = segment_reduce(v, g, 6, mask=np.ones_like(g, bool))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


if HAVE_HYPOTHESIS:
    @hypothesis.settings(max_examples=10, deadline=None)
    @hypothesis.given(t=st.integers(1, 9), n=st.integers(1, 300),
                      c=st.integers(1, 8), seed=st.integers(0, 999),
                      op=st.sampled_from(["sum", "max"]))
    def test_segment_reduce_property(t, n, c, seed, op):
        v, g, m = _seg_case(t, n, c, seed, True)
        got = segment_reduce(v, g, c, op=op, mask=m)
        ref = segment_reduce(v, g, c, op=op, mask=m, use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
else:  # pragma: no cover - depends on environment
    @pytest.mark.parametrize("t,n,c,seed,op",
                             [(1, 1, 1, 0, "sum"), (9, 300, 8, 1, "max"),
                              (4, 129, 5, 2, "sum")])
    def test_segment_reduce_property(t, n, c, seed, op):
        v, g, m = _seg_case(t, n, c, seed, True)
        got = segment_reduce(v, g, c, op=op, mask=m)
        ref = segment_reduce(v, g, c, op=op, mask=m, use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
