"""int8 KV-cache decode path: matches the bf16 cache within quantisation
noise (the §Perf option that makes qwen2-72b decode_32k fit 16GB HBM)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.models.lm import decode_step, forward

SEQ = 16


# gemma3 is excluded from the strict comparison: its sqrt(d_model) embedding
# scale gives an UNTRAINED reduced net ±20 activations, so softmax saturates
# and int8 kv noise flips attention winners (chaotic, not incorrect) — its
# int8 path is covered by the finiteness test below.
@pytest.mark.parametrize("arch", ["granite-8b", "stablelm-1.6b"])
def test_int8_cache_decode_close_to_bf16(arch):
    cfg = get_config(arch).reduced()
    cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, SEQ), 0, cfg.vocab)}
    pre = {"tokens": batch["tokens"][:, : SEQ - 2]}
    outs = {}
    for c in (cfg, cfg8):
        _, _, cache = forward(c, params, pre, mode="prefill",
                              logits_mode="last", max_seq=SEQ)
        lg = []
        for t in range(SEQ - 2, SEQ):
            step_lg, cache = decode_step(c, params, cache,
                                         batch["tokens"][:, t:t + 1],
                                         jnp.asarray(t, jnp.int32))
            lg.append(step_lg[:, 0])
        outs[c.kv_dtype] = jnp.stack(lg, 1).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(outs["int8"]),
                               np.asarray(outs["bf16"]), atol=0.25, rtol=0.25)
    # and against the full forward (teacher forcing)
    full, _, _ = forward(cfg, params, batch, mode="train", remat="none")
    np.testing.assert_allclose(np.asarray(outs["int8"]),
                               np.asarray(full[:, SEQ - 2:], np.float32),
                               atol=0.3, rtol=0.3)


def test_int8_cache_windowed_finite():
    cfg = dataclasses.replace(get_config("gemma3-27b").reduced(),
                              kv_dtype="int8")
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    pre = {"tokens": jax.random.randint(key, (2, SEQ - 2), 0, cfg.vocab)}
    _, _, cache = forward(cfg, params, pre, mode="prefill",
                          logits_mode="last", max_seq=SEQ)
    lg, _ = decode_step(cfg, params, cache, pre["tokens"][:, :1],
                        jnp.asarray(SEQ - 2, jnp.int32))
    assert np.isfinite(np.asarray(lg, np.float32)).all()
