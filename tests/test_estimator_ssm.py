"""Recurrent SSM estimator tests (repro.estimator.ssm + sim wiring).

Pins the load-bearing contracts of the recurrent path: (1) a scan of
O(1) ``ssm_step`` updates reproduces the chunked ``ssm_forward_seq``
pass (same params, different accumulation order); (2)
``forecast_horizon=0`` is BIT-identical to the plain 1-step estimate
under every forecast policy — forecasting is strictly additive; (3) the
engine/serving/online/pool integrations agree with each other and
refuse the windowed-estimator-only switches (int8 serving, quantized
ring) with actionable errors; and (4) the default LSTM estimator's
plain/sched/churn/online paths are pinned bit-identical to the PR 7
program via test-local reimplementations, so the SSM dispatch can never
silently perturb them.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel import scenarios as sc
from repro.channel.scenarios import WINDOW
from repro.core.controller import ControllerConfig
from repro.core.pso import LookupTable
from repro.estimator.model import EstimatorConfig, init_estimator
from repro.estimator.ssm import (N_IQ_FEATS, SSMConfig, episode_features,
                                 init_ssm, iq_features, reduce_forecasts,
                                 ssm_forward_seq, ssm_state_init, ssm_step,
                                 ssm_warm_state)
from repro.estimator.train import fwd, ssm_predict, train_ssm
from repro.models.vgg import FULL, vgg_split_profile
from repro.optim import AdamW
from repro.sim import (DriftConfig, OnlineConfig, SchedulerConfig, buffer_add,
                       buffer_count, buffer_data, buffer_init, drift_init,
                       drift_step, emit_period_samples, estimate_fleet,
                       make_serving_mesh, online_estimate_fleet,
                       online_step_program, run_controllers, run_scheduled,
                       simulate_fleet)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs >= 8 (virtual) devices")

N_SC_TEST = 16
I32 = jnp.int32


def tiny_ssm(seed: int = 0, **kw):
    c = SSMConfig(n_heads=2, head_dim=4, state_dim=4, hidden=8, **kw)
    return c, init_ssm(c, jax.random.PRNGKey(seed))


def tiny_lstm(seed: int = 0):
    e = EstimatorConfig(n_sc=N_SC_TEST, lstm_hidden=8, hidden=8)
    return e, init_estimator(e, jax.random.PRNGKey(seed))


def episode(n: int, T: int = 8, seed: int = 5, iq: bool = False):
    rng = np.random.default_rng(seed)
    names = np.asarray(sc.SCENARIOS)[np.arange(n) % len(sc.SCENARIOS)]
    return sc.gen_episode_batch(names, T, rng, n_sc=N_SC_TEST,
                                include_iq=iq)


def fig6_style_table(prof):
    return LookupTable(ue_name="t", table=np.full(41, 3, np.int32),
                       tp_min_mbps=np.zeros(len(prof.data_bytes)),
                       feasible_prefilter=np.ones(len(prof.data_bytes),
                                                  bool))


def _full_pool_schedule(n, T):
    return sc.ChurnSchedule(arrival_t=np.zeros(n, np.int32),
                            dwell=np.full(n, T, np.int32),
                            ready_end=np.full(T, n, np.int32),
                            horizon=T, max_admits=n)


# ---------------------------------------------------------- core module
def test_config_validation_and_state_accounting():
    with pytest.raises(ValueError, match="n_heads"):
        SSMConfig(n_heads=3, n_groups=2)
    with pytest.raises(ValueError, match="forecast_policy"):
        SSMConfig(forecast_policy="mean")
    with pytest.raises(ValueError, match="forecast_horizon"):
        SSMConfig(forecast_horizon=-1)
    c = SSMConfig()
    assert c.state_shape() == (1, 4, 8, 8)
    assert c.state_bytes() == 4 * 8 * 8 * 4  # f32
    assert c.n_feats == 16
    # hashable: the configs key jit static args and lru caches
    assert hash(c) == hash(SSMConfig())
    assert c != dataclasses.replace(c, forecast_horizon=2)


def test_episode_features_layout():
    ep = episode(3, T=5)
    feats = episode_features(ep.kpms, ep.alloc_ratio)
    assert feats.shape == (3, 5 + WINDOW, 16)
    assert feats.dtype == np.float32
    # channel 15 is the clipped alloc ratio, constant over the trace
    np.testing.assert_allclose(
        feats[..., -1],
        np.broadcast_to(np.clip(ep.alloc_ratio, 0, 1)[:, None],
                        feats.shape[:2]), rtol=1e-6)


def test_episode_features_iq_channels():
    """``include_iq`` appends exactly ``N_IQ_FEATS`` summary channels:
    zeros over the warm-up prefix (no estimate is read there), period
    ``t``'s ``iq_features`` on the index the estimator reads for period
    ``t`` (WINDOW-1+t), KPM/alloc channels untouched."""
    assert SSMConfig(include_iq=True).n_feats == 16 + N_IQ_FEATS
    ep = episode(2, T=5, iq=True)
    base = episode_features(ep.kpms, ep.alloc_ratio)
    feats = episode_features(ep.kpms, ep.alloc_ratio, ep.iq)
    assert feats.shape == (2, 5 + WINDOW, 16 + N_IQ_FEATS)
    np.testing.assert_array_equal(feats[..., :16], base)
    np.testing.assert_array_equal(feats[:, :WINDOW - 1, 16:], 0.0)
    np.testing.assert_array_equal(feats[:, WINDOW - 1 + 5:, 16:], 0.0)
    np.testing.assert_array_equal(feats[:, WINDOW - 1:WINDOW - 1 + 5, 16:],
                                  iq_features(ep.iq))
    with pytest.raises(ValueError, match="periods"):
        episode_features(ep.kpms[:, :WINDOW], ep.alloc_ratio, ep.iq)


def test_include_iq_estimate_and_missing_iq_guard():
    """``include_iq=True`` through ``estimate_fleet`` == the manual
    sequence pass over IQ-augmented features, and an episode generated
    WITHOUT spectrograms is refused with an actionable error (instead of
    silently serving zero IQ channels)."""
    c, params = tiny_ssm(seed=3, include_iq=True)
    ep = episode(4, T=6, iq=True)
    got = estimate_fleet(ep, (c, params))
    fc, _ = ssm_forward_seq(
        c, params,
        jnp.asarray(episode_features(ep.kpms, ep.alloc_ratio, ep.iq)))
    want = np.clip(reduce_forecasts(
        c, np.asarray(fc[:, WINDOW - 1:WINDOW - 1 + ep.n_steps])), 1.0, 130.0)
    np.testing.assert_array_equal(got, want)
    with pytest.raises(ValueError, match="include_iq"):
        estimate_fleet(episode(4, T=6), (c, params))
    with pytest.raises(ValueError, match="include_iq"):
        online_estimate_fleet(episode(4, T=6), (c, params), OnlineConfig())


def test_step_scan_matches_sequence():
    """A scan of O(1) steps from the zero state == the chunked sequence
    pass: same forecasts, same final state (allclose; the chunked scan
    accumulates in a different order)."""
    c, params = tiny_ssm(forecast_horizon=3)
    rng = np.random.default_rng(3)
    feats = jnp.asarray(rng.normal(size=(4, 20, c.n_feats)), jnp.float32)
    fc_seq, s_seq = ssm_forward_seq(c, params, feats)
    state = ssm_state_init(c, (4,))
    fcs = []
    for t in range(20):
        state, fc_t = ssm_step(c, params, state, feats[:, t])
        fcs.append(np.asarray(fc_t))
    np.testing.assert_allclose(np.stack(fcs, 1), np.asarray(fc_seq),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_seq),
                               atol=1e-5, rtol=1e-5)


def test_warm_state_then_steps_matches_full_sequence():
    """Warmup via ``ssm_warm_state`` + stepping the remainder == running
    the whole trace — the serving paths' split is seamless."""
    c, params = tiny_ssm(seed=1)
    rng = np.random.default_rng(4)
    feats = jnp.asarray(rng.normal(size=(3, 16, c.n_feats)), jnp.float32)
    _, s_full = ssm_forward_seq(c, params, feats)
    state = ssm_warm_state(c, params, feats[:, :10])
    for t in range(10, 16):
        state, _ = ssm_step(c, params, state, feats[:, t])
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_full),
                               atol=1e-5, rtol=1e-5)


def test_forecast_horizon_zero_is_bit_identical_current_estimate():
    """The K=0 pin: a K>0 config's column 0 IS the K=0 forecast array,
    bit for bit, and ``reduce_forecasts`` at K=0 returns column 0
    unchanged under EVERY policy — forecasting never perturbs the
    1-step estimate."""
    c0, params = tiny_ssm()
    c4 = dataclasses.replace(c0, forecast_horizon=4,
                             forecast_policy="discount")
    rng = np.random.default_rng(5)
    feats = jnp.asarray(rng.normal(size=(4, 12, c0.n_feats)), jnp.float32)
    fc0, s0 = ssm_forward_seq(c0, params, feats)
    fc4, s4 = ssm_forward_seq(c4, params, feats)
    assert fc0.shape[-1] == 1 and fc4.shape[-1] == 5
    np.testing.assert_array_equal(np.asarray(fc4[..., 0]),
                                  np.asarray(fc0[..., 0]))
    np.testing.assert_array_equal(np.asarray(s4), np.asarray(s0))
    for policy in ("last", "min", "discount"):
        ck0 = dataclasses.replace(c0, forecast_policy=policy)
        np.testing.assert_array_equal(reduce_forecasts(ck0, np.asarray(fc0)),
                                      np.asarray(fc0)[..., 0])


def test_reduce_forecasts_policies():
    c, _ = tiny_ssm(forecast_horizon=2)
    fc = np.array([[3.0, 1.0, 5.0], [2.0, 2.0, 2.0]])
    last = reduce_forecasts(dataclasses.replace(c, forecast_policy="last"), fc)
    np.testing.assert_array_equal(last, [3.0, 2.0])
    mn = reduce_forecasts(dataclasses.replace(c, forecast_policy="min"), fc)
    np.testing.assert_array_equal(mn, [1.0, 2.0])
    d = dataclasses.replace(c, forecast_policy="discount",
                            forecast_discount=0.5)
    disc = reduce_forecasts(d, fc)
    w = np.array([1.0, 0.5, 0.25]) / 1.75
    np.testing.assert_allclose(disc, fc @ w, rtol=1e-6)
    # a convex combination: always within the forecast envelope
    assert (disc >= fc.min(-1) - 1e-9).all()
    assert (disc <= fc.max(-1) + 1e-9).all()


# ------------------------------------------------------- engine dispatch
def test_estimate_fleet_ssm_matches_manual_sequence():
    """The engine's recurrent arm == the manual composition: features ->
    one sequence pass -> the WINDOW-1 alignment slice -> policy reduce ->
    clip, bit for bit."""
    c, params = tiny_ssm(forecast_horizon=2, forecast_policy="min")
    ep = episode(6, T=8)
    got = estimate_fleet(ep, (c, params))
    feats = episode_features(ep.kpms, ep.alloc_ratio)
    fc, _ = ssm_forward_seq(c, params, jnp.asarray(feats))
    want = np.clip(reduce_forecasts(
        c, np.asarray(fc[:, WINDOW - 1:WINDOW - 1 + ep.n_steps])), 1.0, 130.0)
    np.testing.assert_array_equal(got, want)
    assert got.shape == (6, 8)


def test_estimate_fleet_forecast_policy_ordering():
    """Same params, same episode: the min policy can never exceed the
    last policy (clip is monotone), and discount stays within them and
    the envelope."""
    c, params = tiny_ssm(seed=2, forecast_horizon=3)
    ep = episode(6, T=8, seed=7)
    est = {p: estimate_fleet(
        ep, (dataclasses.replace(c, forecast_policy=p), params))
        for p in ("last", "min", "discount")}
    assert (est["min"] <= est["last"] + 1e-6).all()
    assert (est["min"] <= est["discount"] + 1e-6).all()
    # K=0 with any policy == the horizonless config
    e0 = estimate_fleet(ep, (dataclasses.replace(c, forecast_horizon=0,
                                                 forecast_policy="min"),
                             params))
    np.testing.assert_array_equal(
        e0, estimate_fleet(ep, (dataclasses.replace(c, forecast_horizon=0),
                                params)))


def test_ssm_refuses_windowed_only_switches():
    c, params = tiny_ssm()
    ep = episode(2, T=4)
    with pytest.raises(ValueError, match="int8 serving"):
        estimate_fleet(ep, (c, params), quant="int8")
    lean = sc.gen_episode_batch(["none", "cci"], 4,
                                np.random.default_rng(0), include_iq=False,
                                include_kpms=False)
    with pytest.raises(ValueError, match="include_kpms"):
        estimate_fleet(lean, (c, params))
    with pytest.raises(ValueError, match="ring_quant"):
        buffer_init(8, c, quant="int8")
    with pytest.raises(ValueError, match="include_kpms"):
        online_estimate_fleet(lean, (c, params), OnlineConfig())


@multi_device
def test_sharded_ssm_estimate_matches_unsharded():
    """The mesh-sharded per-period step program == the single-device
    sequence pass (allclose): same math, state sharded over batch."""
    c, params = tiny_ssm(forecast_horizon=2, forecast_policy="discount")
    ep = episode(8, T=6)
    ref = estimate_fleet(ep, (c, params))
    got = estimate_fleet(ep, (c, params), serving=make_serving_mesh("8x1"))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- training
def test_train_ssm_reduces_loss_and_predict_aligns():
    c, _ = tiny_ssm()
    ep = episode(12, T=10, seed=11)
    data = {"feats": episode_features(ep.kpms, ep.alloc_ratio),
            "tp": np.asarray(ep.tp_mbps, np.float32)}
    params, hist, metrics = train_ssm(c, data, steps=200, batch=8,
                                      lr=3e-3, log_every=50, eval_data=data)
    assert hist[-1][1] < hist[0][1] * 0.8
    pred = ssm_predict(c, params, data)
    assert pred.shape == (12, 10)
    assert metrics is not None and np.isfinite(metrics[1])
    # tail alignment: the last label column reads sequence index S-2
    fc, _ = ssm_forward_seq(c, params, jnp.asarray(data["feats"][:4]))
    np.testing.assert_allclose(pred[:4, -1], np.asarray(fc[:, -2, 0]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------- online loop
def test_online_ssm_adapts_and_beats_frozen():
    """The recurrent closed loop learns: forced triggers reduce the late
    RMSE below the frozen random-init estimator's, loss falls across
    bursts, and the per-period cost never re-reads history (the ring
    stores O(1) (state, report, label) events)."""
    c, params = tiny_ssm()
    ep = episode(16, T=16, seed=9)
    ocfg = OnlineConfig(capacity=256, batch=64, steps=10, lr=3e-3,
                        min_fill=16, seed=1,
                        drift=DriftConfig(calibrate_periods=2,
                                          threshold_mbps=0.0, patience=1,
                                          cooldown=1))
    frozen = estimate_fleet(ep, (c, params))
    est, stats = online_estimate_fleet(ep, (c, params), ocfg)
    assert stats.n_adaptations >= 3
    assert stats.train_steps == stats.n_adaptations * ocfg.steps
    assert stats.train_loss[-1] < stats.train_loss[0]
    tp = np.asarray(ep.tp_mbps, float)
    late = slice(ep.n_steps // 2, None)
    rmse_onl = float(np.sqrt(np.mean((est[:, late] - tp[:, late]) ** 2)))
    rmse_frz = float(np.sqrt(np.mean((frozen[:, late] - tp[:, late]) ** 2)))
    assert rmse_onl < rmse_frz


def test_online_ssm_no_trigger_matches_frozen():
    """Monitor never trips -> the per-step loop degenerates to the frozen
    sequence estimate (allclose; step vs chunked accumulation)."""
    c, params = tiny_ssm(forecast_horizon=2, forecast_policy="min")
    ep = episode(4, T=6)
    ocfg = OnlineConfig(capacity=32, batch=8, steps=2, min_fill=4,
                        drift=DriftConfig(calibrate_periods=1,
                                          threshold_mbps=1e9, patience=1))
    est, stats = online_estimate_fleet(ep, (c, params), ocfg)
    np.testing.assert_allclose(est, estimate_fleet(ep, (c, params)),
                               rtol=1e-4, atol=1e-4)
    assert stats.n_adaptations == 0 and stats.train_steps == 0


@multi_device
def test_online_ssm_sharded_matches_unsharded():
    c, params = tiny_ssm()
    ep = episode(8, T=6)
    ocfg = OnlineConfig(capacity=64, batch=16, steps=3, min_fill=8,
                        drift=DriftConfig(calibrate_periods=2,
                                          threshold_mbps=0.0, patience=1,
                                          cooldown=1))
    est_u, st_u = online_estimate_fleet(ep, (c, params), ocfg)
    est_s, st_s = online_estimate_fleet(ep, (c, params), ocfg,
                                        serving=make_serving_mesh("8x1"))
    np.testing.assert_allclose(est_s, est_u, rtol=1e-4, atol=1e-3)
    np.testing.assert_array_equal(st_s.adapted, st_u.adapted)
    assert st_s.n_adaptations == st_u.n_adaptations > 0


# ------------------------------------------------------------- slot pool
def test_pool_ssm_full_pool_matches_batch_engine():
    """Degenerate churn (all sessions at t=0, capacity = sessions) with
    the recurrent estimator == the batch engine: bit-identical splits
    and estimates — slot i is session i with age t == period t."""
    c, params = tiny_ssm(forecast_horizon=1, forecast_policy="min")
    n, T = 6, 8
    ep = episode(n, T=T, seed=13)
    prof = vgg_split_profile(FULL)
    table = fig6_style_table(prof)
    cfg = ControllerConfig(0.5, 2, 3)
    base = simulate_fleet(ep, table, prof, cfg, estimator=(c, params))
    pool = simulate_fleet(ep, table, prof, cfg, estimator=(c, params),
                          churn=_full_pool_schedule(n, T), capacity=n)
    assert pool.active.all()
    np.testing.assert_array_equal(base.splits, pool.splits)
    np.testing.assert_array_equal(base.est_tp, pool.est_tp)


def test_pool_ssm_online_composes():
    """The recurrent online arm drives the slot pool: per-slot states
    reset to the session's warm state on admit, masked ring ingestion,
    and the adaptation trace comes back."""
    rng = np.random.default_rng(19)
    T, capacity = 12, 6
    ccfg = sc.ChurnConfig(arrival_rate=2.0, mean_dwell=4.0, max_dwell=6)
    schedule = sc.make_churn_schedule(ccfg, T, rng)
    if schedule.n_sessions == 0:  # pragma: no cover - rate keeps M > 0
        pytest.skip("empty arrival realisation")
    names = np.asarray(sc.SCENARIOS)[
        np.arange(schedule.n_sessions) % len(sc.SCENARIOS)]
    sessions = sc.gen_episode_batch(names, schedule.max_dwell, rng,
                                    include_iq=False)
    c, params = tiny_ssm()
    prof = vgg_split_profile(FULL)
    table = fig6_style_table(prof)
    cfg = ControllerConfig(0.5, 2, 3)
    ocfg = OnlineConfig(capacity=64, batch=8, steps=2, min_fill=8,
                        drift=DriftConfig(calibrate_periods=2,
                                          threshold_mbps=0.0, patience=1,
                                          cooldown=1))
    res = simulate_fleet(sessions, table, prof, cfg, churn=schedule,
                         capacity=capacity, estimator=(c, params),
                         online=ocfg)
    assert res.online is not None and res.online.rmse.shape == (T,)
    assert res.online.n_adaptations > 0
    assert res.active.shape == (capacity, T)
    assert (res.est_tp[~res.active] == 0.0).all()
    assert (res.est_tp[res.active] >= 1.0).all()
    # the ring only ever ingested live-slot events
    assert res.online.buffer_fill <= min(64, int(res.active.sum()))
    # masked ingestion needs ring room for every slot
    with pytest.raises(ValueError, match="cover the pool"):
        simulate_fleet(sessions, table, prof, cfg, churn=schedule,
                       capacity=capacity, estimator=(c, params),
                       online=OnlineConfig(capacity=4))


# ----------------------------------------- PR 7 LSTM bit-identity pins
def test_lstm_plain_path_bit_identical():
    """The windowed estimator's batch path must BE the PR 7 program: the
    chunked multi-period forward == the per-period ``fwd`` loop, clipped,
    bit for bit (the SSM dispatch branch can never perturb it)."""
    e, params = tiny_lstm()
    ep = episode(5, T=7, iq=True)
    got = estimate_fleet(ep, (e, params))
    wins = ep.kpm_windows(normalize=True).astype(np.float32)
    alloc = jnp.asarray(ep.alloc_ratio, jnp.float32)
    want = np.empty((5, 7))
    for t in range(7):
        want[:, t] = np.asarray(fwd(
            e, params, jnp.asarray(wins[:, t]),
            jnp.asarray(ep.iq[:, t], jnp.float32), alloc))
    np.testing.assert_array_equal(got, np.clip(want, 1.0, 130.0))


def test_lstm_sched_path_bit_identical():
    """simulate_fleet(sched=...) with the LSTM == the manual
    estimate_fleet -> run_scheduled composition, bit for bit."""
    e, params = tiny_lstm()
    n, T, n_cells = 6, 7, 2
    ep = episode(n, T=T, iq=True, seed=21)
    prof = vgg_split_profile(FULL)
    table = fig6_style_table(prof)
    cfg = ControllerConfig(0.5, 2, 3)
    grid = np.repeat((np.arange(n) % n_cells)[:, None], T, axis=1)
    scfg = SchedulerConfig("pf", pf_beta=0.3)
    res = simulate_fleet(ep, table, prof, cfg, estimator=(e, params),
                         sched=scfg, cell_idx=grid, n_cells=n_cells)
    est = estimate_fleet(ep, (e, params))
    tables = np.broadcast_to(table.table, (n, len(table.table)))
    splits, shares = run_scheduled(tables, est, cfg, cfg.fallback_split,
                                   scfg, n_cells, grid,
                                   np.asarray(ep.tp_mbps, float))
    np.testing.assert_array_equal(res.splits, splits)
    np.testing.assert_array_equal(res.prb_share, shares)
    np.testing.assert_array_equal(res.est_tp, est * shares)


def test_lstm_churn_path_bit_identical():
    """Degenerate churn with the LSTM estimator == the batch engine:
    bit-identical splits and estimates."""
    e, params = tiny_lstm()
    n, T = 6, 8
    ep = episode(n, T=T, iq=True, seed=23)
    prof = vgg_split_profile(FULL)
    table = fig6_style_table(prof)
    cfg = ControllerConfig(0.5, 2, 3)
    base = simulate_fleet(ep, table, prof, cfg, estimator=(e, params))
    pool = simulate_fleet(ep, table, prof, cfg, estimator=(e, params),
                          churn=_full_pool_schedule(n, T), capacity=n)
    assert pool.active.all()
    np.testing.assert_array_equal(base.splits, pool.splits)
    np.testing.assert_array_equal(base.est_tp, pool.est_tp)


def test_lstm_online_loop_bit_identical():
    """The LSTM online loop == a test-local reimplementation from the
    public pieces (predict, ring, monitor, step program) under identical
    rng/key streams: same estimates and final params, bit for bit."""
    e, params0 = tiny_lstm()
    ep = episode(8, T=8, iq=True, seed=25)
    ocfg = OnlineConfig(capacity=64, batch=16, steps=3, min_fill=8, seed=4,
                        drift=DriftConfig(calibrate_periods=2,
                                          threshold_mbps=0.0, patience=1,
                                          cooldown=1))
    est, stats = online_estimate_fleet(ep, (e, params0), ocfg)
    assert stats.n_adaptations > 0  # the pin must cover adapted periods
    # --- reference loop, spelled out ---
    n, T = ep.n_ues, ep.n_steps
    wins = ep.kpm_windows(normalize=True).astype(np.float32)
    opt = AdamW(lr=ocfg.lr, weight_decay=ocfg.weight_decay,
                clip_norm=ocfg.clip_norm)
    params, opt_state = params0, opt.init(params0)
    step_fn = online_step_program(e, opt, None)
    buf = buffer_init(ocfg.capacity, e)
    dstate = drift_init()
    rng = np.random.default_rng(ocfg.seed)
    key = jax.random.PRNGKey(ocfg.seed)
    ref = np.empty((n, T))
    alloc_d = jnp.asarray(ep.alloc_ratio, jnp.float32)
    for t in range(T):
        s = emit_period_samples(ep, t, wins)
        kpms_t = jnp.asarray(s["kpms"])
        iq_t = jnp.asarray(s["iq"])
        ref[:, t] = np.clip(np.asarray(
            fwd(e, params, kpms_t, iq_t, alloc_d)), 1.0, 130.0)
        rmse_t = float(np.sqrt(np.mean((ref[:, t] - s["tp"]) ** 2)))
        buf = buffer_add(buf, kpms_t, iq_t, alloc_d,
                         jnp.asarray(s["tp"], jnp.float32))
        fill = buffer_count(buf)
        dstate, fired = drift_step(ocfg.drift, dstate, rmse_t,
                                   armed=fill >= ocfg.min_fill)
        if fired:
            data = buffer_data(buf)
            for _ in range(ocfg.steps):
                idx = jnp.asarray(rng.integers(0, fill, ocfg.batch), I32)
                key, sub = jax.random.split(key)
                params, opt_state, _ = step_fn(params, opt_state, data,
                                               idx, sub)
    np.testing.assert_array_equal(est, ref)
    for a, b in zip(jax.tree.leaves(stats.params), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
