"""Telemetry-layer tests: telemetry-on bit-identity against the default
programs across the plain / scheduled / churn / online paths, masked
metric invariants (inactive slots contribute nothing, histogram totals
equal the active-sample count, the event ring never overflows silently),
decode/export round trips, and the stage-timing helpers. Property tests
run through hypothesis when available, otherwise a fixed-seed sweep of
the same checks (the suite's standard pattern)."""
try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel import scenarios as sc
from repro.core.controller import ControllerConfig
from repro.core.pso import LookupTable
from repro.estimator.model import EstimatorConfig, init_estimator
from repro.models.vgg import FULL, vgg_split_profile
from repro.sim import (DriftConfig, OnlineConfig, SchedulerConfig,
                       TelemetryConfig, TelemetryRecord, simulate_fleet,
                       timed, timed_stages, to_jsonl, to_prometheus)
from repro.sim import telemetry as tel

F32 = jnp.float32
I32 = jnp.int32


@pytest.fixture(scope="module")
def prof():
    return vgg_split_profile(FULL)


@pytest.fixture(scope="module")
def table(prof):
    return LookupTable(ue_name="t", table=np.full(41, 3, np.int32),
                       tp_min_mbps=np.zeros(len(prof.data_bytes)),
                       feasible_prefilter=np.ones(len(prof.data_bytes),
                                                  bool))


CFG = ControllerConfig(ewma_alpha=0.5, hysteresis_steps=2, fallback_split=3)


def _episode(n, T=6, seed=5, **kw):
    rng = np.random.default_rng(seed)
    scen = np.asarray(sc.SCENARIOS)[np.arange(n) % len(sc.SCENARIOS)]
    return sc.gen_episode_batch(scen, T, rng, n_sc=16, **kw)


def _churn(T=12, seed=7, rate=4.0, dwell=5.0):
    rng = np.random.default_rng(seed)
    schedule = sc.make_churn_schedule(
        sc.ChurnConfig(arrival_rate=rate, mean_dwell=dwell), T, rng)
    scen = np.asarray(sc.SCENARIOS, object)[
        np.arange(schedule.n_sessions) % len(sc.SCENARIOS)]
    sessions = sc.gen_episode_batch(scen, schedule.max_dwell, rng, n_sc=16)
    return schedule, sessions


def _tiny_estimator(seed=0):
    e = EstimatorConfig(n_sc=16, lstm_hidden=8, hidden=8)
    return e, init_estimator(e, jax.random.PRNGKey(seed))


def _assert_identical(base, res):
    np.testing.assert_array_equal(base.splits, res.splits)
    np.testing.assert_array_equal(base.est_tp, res.est_tp)
    np.testing.assert_array_equal(np.nan_to_num(base.delay_s),
                                  np.nan_to_num(res.delay_s))


# ------------------------------------------------ bit-identity pins
def test_plain_engine_identical(prof, table):
    ep = _episode(8)
    base = simulate_fleet(ep, table, prof, CFG)
    res = simulate_fleet(ep, table, prof, CFG, telemetry=TelemetryConfig())
    _assert_identical(base, res)
    assert base.telemetry is None and res.telemetry is not None


def test_sched_engine_identical(prof, table):
    ep = _episode(8)
    cell = np.repeat((np.arange(8) % 2)[:, None], 6, axis=1).astype(np.int32)
    cell[:4, 3:] = 1 - cell[:4, 3:]  # mid-episode handover for 4 UEs
    kw = dict(sched=SchedulerConfig(policy="pf"), cell_idx=cell, n_cells=2)
    base = simulate_fleet(ep, table, prof, CFG, **kw)
    res = simulate_fleet(ep, table, prof, CFG,
                         telemetry=TelemetryConfig(), **kw)
    _assert_identical(base, res)
    np.testing.assert_array_equal(base.prb_share, res.prb_share)


def test_churn_pool_identical(prof, table):
    schedule, sessions = _churn()
    kw = dict(churn=schedule, capacity=16)
    base = simulate_fleet(sessions, table, prof, CFG, **kw)
    res = simulate_fleet(sessions, table, prof, CFG,
                         telemetry=TelemetryConfig(), **kw)
    _assert_identical(base, res)
    np.testing.assert_array_equal(base.active, res.active)
    rec = res.telemetry
    assert rec.admitted == base.lifecycle.n_admitted
    assert rec.departed == int(base.lifecycle.departed.sum())


def test_online_engine_identical_and_events(prof, table):
    est = _tiny_estimator()
    ep = _episode(8, T=10)
    ocfg = OnlineConfig(capacity=256, batch=16, steps=2, min_fill=8,
                        drift=DriftConfig(threshold_mbps=0.1,
                                          calibrate_periods=2, patience=1,
                                          cooldown=2))
    kw = dict(estimator=est, online=ocfg)
    base = simulate_fleet(ep, table, prof, CFG, **kw)
    res = simulate_fleet(ep, table, prof, CFG,
                         telemetry=TelemetryConfig(), **kw)
    _assert_identical(base, res)
    kinds = {e.kind for e in res.telemetry.events}
    # the untrained estimator's RMSE trips the absolute drift threshold
    assert "drift_trigger" in kinds and "burst_end" in kinds


# ------------------------------------------------ metric invariants
def _invariants(rec, res):
    n_act = (int(np.asarray(res.active).sum()) if res.active is not None
             else int(np.prod(res.splits.shape)))  # engine: all UEs live
    assert rec.active_steps == n_act
    for name in ("split", "err_mbps", "delay_s", "share"):
        assert sum(rec.hists[name]["counts"]) == rec.active_steps, name
    assert sum(rec.hists["occupancy"]["counts"]) == rec.periods
    assert rec.dropped_events == 0
    assert len(rec.series["occupancy"]) == rec.periods


def test_engine_invariants(prof, table):
    res = simulate_fleet(_episode(8), table, prof, CFG,
                         telemetry=TelemetryConfig())
    _invariants(res.telemetry, res)


def test_churn_invariants(prof, table):
    schedule, sessions = _churn()
    res = simulate_fleet(sessions, table, prof, CFG, churn=schedule,
                         capacity=16, telemetry=TelemetryConfig())
    _invariants(res.telemetry, res)
    admits = [e for e in res.telemetry.events if e.kind == "admit"]
    assert len(admits) == res.telemetry.admitted
    assert all(e.value >= 0 for e in admits)  # queue latency in periods


def test_event_ring_overflow_not_silent(prof, table):
    schedule, sessions = _churn()
    res = simulate_fleet(sessions, table, prof, CFG, churn=schedule,
                         capacity=16,
                         telemetry=TelemetryConfig(events_capacity=4))
    rec = res.telemetry
    assert len(rec.events) <= 4
    assert rec.dropped_events > 0  # overflow is counted, never silent


# ------------------------------------------- masked-step property tests
def _random_step_inputs(seed, s=16):
    rng = np.random.default_rng(seed)
    split = rng.integers(-1, 41, s).astype(np.int32)
    est = rng.uniform(0.5, 130.0, s).astype(np.float32)
    true = rng.uniform(0.5, 130.0, s).astype(np.float32)
    share = rng.uniform(0.0, 1.0, s).astype(np.float32)
    active = rng.random(s) < 0.6
    dconst = rng.uniform(0.01, 0.2, 42).astype(np.float32)
    dbytes = rng.uniform(1e3, 1e6, 42).astype(np.float32)
    return split, est, true, share, active, dconst, dbytes


def _step(cfg, ts, split, est, true, share, active, dconst, dbytes):
    return tel.telemetry_step(
        cfg, ts, period=0, split=jnp.asarray(split),
        est_tp=jnp.asarray(est), true_tp=jnp.asarray(true),
        share=jnp.asarray(share), active=jnp.asarray(active),
        dconst=jnp.asarray(dconst), dbytes=jnp.asarray(dbytes))


def check_inactive_contribute_nothing(seed):
    """Masked update == the same update on the compacted active rows."""
    cfg = TelemetryConfig()
    split, est, true, share, active, dconst, dbytes = \
        _random_step_inputs(seed)
    if not active.any():
        active[0] = True
    ts0 = tel.telemetry_init(cfg)
    masked, row_m = _step(cfg, ts0, split, est, true, share, active,
                          dconst, dbytes)
    a = active
    compact, row_c = _step(cfg, ts0, split[a], est[a], true[a], share[a],
                           np.ones(a.sum(), bool), dconst, dbytes)
    assert int(masked.active_steps) == int(compact.active_steps)
    for f in ("split_hist", "err_hist", "delay_hist", "share_hist"):
        np.testing.assert_array_equal(np.asarray(getattr(masked, f)),
                                      np.asarray(getattr(compact, f)))
    # per-slot stat channels (occupancy differs by construction: the
    # compacted pool has a different slot count)
    np.testing.assert_allclose(np.asarray(masked.sums)[:5],
                               np.asarray(compact.sums)[:5], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(masked.mins)[:5],
                                  np.asarray(compact.mins)[:5])
    np.testing.assert_array_equal(np.asarray(masked.maxs)[:5],
                                  np.asarray(compact.maxs)[:5])
    assert float(row_m.err_sq_sum) == pytest.approx(
        float(row_c.err_sq_sum), rel=1e-6)


def check_hist_totals(seed):
    cfg = TelemetryConfig()
    split, est, true, share, active, dconst, dbytes = \
        _random_step_inputs(seed)
    ts, _ = _step(cfg, tel.telemetry_init(cfg), split, est, true, share,
                  active, dconst, dbytes)
    n_act = int(active.sum())
    for f in ("split_hist", "err_hist", "delay_hist", "share_hist"):
        assert int(np.asarray(getattr(ts, f)).sum()) == n_act, f
    assert int(np.asarray(ts.occ_hist).sum()) == 1  # one sample/period


def check_ring_never_silent(seed, capacity):
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, 24))
    valid = rng.random(k) < 0.7
    ring = tel.ring_init(capacity)
    ring = tel.ring_push(ring, jnp.full((k,), tel.EV_ADMIT, I32),
                         jnp.zeros((k,), I32), jnp.arange(k, dtype=I32),
                         jnp.zeros((k,), F32), jnp.asarray(valid))
    stored, dropped = int(ring.count), int(ring.dropped)
    assert stored <= capacity
    assert stored + dropped == int(valid.sum())  # every event accounted
    # stored lanes are the first valid ones, in lane order (keep-first)
    want = np.flatnonzero(valid)[:stored]
    np.testing.assert_array_equal(np.asarray(ring.arg)[:stored], want)


if HAVE_HYPOTHESIS:
    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.given(seed=st.integers(0, 10_000))
    def test_inactive_contribute_nothing(seed):
        check_inactive_contribute_nothing(seed)

    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.given(seed=st.integers(0, 10_000))
    def test_hist_totals(seed):
        check_hist_totals(seed)

    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.given(seed=st.integers(0, 10_000),
                      capacity=st.integers(1, 12))
    def test_ring_never_silent(seed, capacity):
        check_ring_never_silent(seed, capacity)
else:  # pragma: no cover - depends on environment
    @pytest.mark.parametrize("seed", range(8))
    def test_inactive_contribute_nothing(seed):
        check_inactive_contribute_nothing(seed)

    @pytest.mark.parametrize("seed", range(8))
    def test_hist_totals(seed):
        check_hist_totals(seed)

    @pytest.mark.parametrize("seed,capacity",
                             [(s, c) for s in range(4) for c in (1, 4, 12)])
    def test_ring_never_silent(seed, capacity):
        check_ring_never_silent(seed, capacity)


# ------------------------------------------------ decode + exporters
def test_record_roundtrip_and_exporters(prof, table, tmp_path):
    schedule, sessions = _churn()
    res = simulate_fleet(sessions, table, prof, CFG, churn=schedule,
                         capacity=16, telemetry=TelemetryConfig())
    rec = res.telemetry
    # dict round trip
    back = TelemetryRecord.from_dict(rec.to_dict())
    assert back.admitted == rec.admitted
    assert back.active_steps == rec.active_steps
    assert [e.kind for e in back.events] == [e.kind for e in rec.events]
    # JSON lines: one line per period + the summary line
    path = tmp_path / "run.jsonl"
    to_jsonl(rec, str(path))
    lines = path.read_text().strip().splitlines()
    assert len(lines) == rec.periods + 1
    summary = json.loads(lines[-1])["summary"]
    assert summary["admitted"] == rec.admitted
    # Prometheus text exposition: counters + cumulative histogram
    prom = to_prometheus(rec)
    assert f"fleet_admitted_total {rec.admitted}" in prom
    assert 'le="+Inf"' in prom
    # the +Inf bucket of each histogram equals its _count
    for name in ("split", "err_mbps"):
        total = sum(rec.hists[name]["counts"])
        assert f'fleet_{name}_count {total}' in prom


def test_event_timeline_filter(prof, table):
    schedule, sessions = _churn()
    rec = simulate_fleet(sessions, table, prof, CFG, churn=schedule,
                         capacity=16,
                         telemetry=TelemetryConfig()).telemetry
    only = rec.event_timeline(("admit",))
    assert only and all(e.kind == "admit" for e in only)
    periods = [e.period for e in rec.events]
    assert periods == sorted(periods)  # decode sorts by period


# ------------------------------------------------ stage-timing helpers
def test_timed_and_stages():
    stat = timed(lambda: None, reps=3)
    assert stat.best >= 0 and stat.median >= stat.best >= 0
    assert stat.spread >= 0
    assert set(stat.ms()) == {"best_ms", "median_ms", "spread_ms"}
    out = timed_stages({"a": lambda: None, "b": lambda: sum(range(10))},
                       reps=2)
    assert set(out) == {"a", "b"}
    assert all(s.best >= 0 for s in out.values())


def test_stopwatch():
    from benchmarks.common import stopwatch
    with stopwatch() as sw:
        sum(range(1000))
    assert sw.seconds > 0
