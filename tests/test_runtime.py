"""Fault tolerance, elastic checkpointing, stragglers, data pipeline,
gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore, save
from repro.configs import get_config
from repro.data.pipeline import make_batch_fn
from repro.optim.compress import compressed_grads, init_error
from repro.runtime.stragglers import Action, StragglerWatchdog
from repro.runtime.trainer import InjectedFailure, Trainer, TrainerConfig


def tiny_cfg():
    return get_config("stablelm-1.6b").reduced()


def tc(tmpdir, **kw):
    base = dict(seq=16, global_batch=4, steps=12, ckpt_every=4,
                ckpt_dir=str(tmpdir), warmup=2)
    base.update(kw)
    return TrainerConfig(**base)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)}}
    save(tmp_path, 3, tree)
    back = restore(tmp_path, 3, tree)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), tree, back)


def test_checkpoint_elastic_reshard(tmp_path):
    """Save unsharded, restore onto an explicit sharding (mesh-agnostic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save(tmp_path, 0, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    back = restore(tmp_path, 0, tree, sh)
    assert back["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))


def test_manager_keeps_last_k(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.zeros(3)}
    for s in (0, 1, 2, 3):
        m.save(s, tree, blocking=True)
    m.wait()
    steps = sorted(d.name for d in tmp_path.iterdir())
    assert steps == ["step_00000002", "step_00000003"]


def test_failure_injection_and_resume_continues_trajectory(tmp_path):
    """Crash at step 7, restart with --resume: the combined loss history
    must equal an uninterrupted run (pure-function-of-step data + saved
    optimizer state)."""
    cfg = tiny_cfg()
    ref_state, ref_hist = Trainer(cfg, tc(tmp_path / "ref")).run()

    t1 = Trainer(cfg, tc(tmp_path / "ft", fail_at_step=7))
    with pytest.raises(InjectedFailure):
        t1.run()
    t2 = Trainer(cfg, tc(tmp_path / "ft"))
    _, hist2 = t2.run(resume=True)
    combined = {int(s): l for s, l in np.concatenate([
        np.array(t1.history), hist2])}
    ref = {int(s): l for s, l in ref_hist}
    assert set(combined) == set(ref)
    for s in ref:
        np.testing.assert_allclose(combined[s], ref[s], rtol=2e-4, atol=2e-4)


def test_straggler_watchdog_escalates():
    w = StragglerWatchdog(threshold=2.0, patience=2, warmup=3)
    acts = [w.update(1.0) for _ in range(5)]
    assert all(a is Action.NONE for a in acts)
    assert w.update(5.0) is Action.WARN
    assert w.update(5.0) is Action.EXCLUDE
    assert w.excluded


def test_straggler_watchdog_recovers():
    w = StragglerWatchdog(threshold=2.0, patience=3, warmup=2)
    for _ in range(4):
        w.update(1.0)
    assert w.update(4.0) is Action.WARN
    assert w.update(1.0) is Action.NONE  # strike reset
    assert not w.excluded


def test_data_pipeline_deterministic():
    cfg = tiny_cfg()
    f1 = make_batch_fn(cfg, 32, 4, seed=7)
    f2 = make_batch_fn(cfg, 32, 4, seed=7)
    b1, b2 = f1(11), f2(11)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(f1(11)["tokens"], f1(12)["tokens"])


def test_grad_compression_error_feedback():
    """Error feedback: mean of compressed grads over steps converges to the
    true mean (bias telescopes); without it, bias persists."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)}
    err = init_error(g_true)
    acc = jnp.zeros_like(g_true["w"])
    n = 30
    for _ in range(n):
        ghat, err = compressed_grads(g_true, err)
        acc = acc + ghat["w"]
    drift = float(jnp.abs(acc / n - g_true["w"]).max())
    q1, _ = compressed_grads(g_true, init_error(g_true))
    one_step = float(jnp.abs(q1["w"] - g_true["w"]).max())
    assert drift < one_step / 5  # telescoping beats single-shot noise
    assert drift < 0.01
