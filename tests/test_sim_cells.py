"""Multi-cell layer tests: scheduler properties (PRB conservation,
no-starvation under proportional-fair, permutation-equivariance), the
1-cell/no-coupling equivalence regression against the PR-2 engine path,
load-coupled interference, and the cells orchestration. Property tests run
through hypothesis when available, otherwise a fixed-seed sweep of the
same checks (the suite's standard pattern)."""
try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

import numpy as np
import pytest

from repro.channel import scenarios as sc
from repro.channel import throughput as tpm
from repro.core.controller import ControllerConfig
from repro.core.energy import EDGE_A40X2, UE_VM_2CORE
from repro.core.objective import Constraints, Weights
from repro.core.pso import pso_vectorized
from repro.models.vgg import FULL, vgg_split_profile
from repro.sim import (POLICIES, SchedulerConfig, attach_ring,
                       build_cells_episode, cell_load,
                       coupled_interference_mw, handover_grid, jain_index,
                       ring_coupling, scheduler_init, scheduler_step,
                       simulate_cells, simulate_fleet)

N_CELLS = 3


def _random_fleet(rng, n):
    """Random cell assignment (every cell represented) + distinct rates."""
    cell_idx = np.concatenate([np.arange(N_CELLS),
                               rng.integers(0, N_CELLS, n - N_CELLS)])
    rate = rng.uniform(0.5, 130.0, n)
    return cell_idx.astype(np.int32), rate


def _run_steps(cfg, cell_idx, rates):
    """Advance the scheduler over the (T, N) rate rows; returns (T, N)
    shares and the final state."""
    state = scheduler_init(rates.shape[1])
    shares = []
    for r in rates:
        state, s = scheduler_step(cfg, N_CELLS, state, cell_idx, r)
        shares.append(np.asarray(s))
    return np.stack(shares), state


def _check_conservation(seed, policy):
    """Allocations sum to the cell budget (every period, every non-empty
    cell) and each share is a valid fraction."""
    rng = np.random.default_rng(seed)
    n = 17
    cell_idx, _ = _random_fleet(rng, n)
    rates = rng.uniform(0.5, 130.0, (6, n))
    cfg = SchedulerConfig(policy=policy, n_prb=100)
    shares, _ = _run_steps(cfg, cell_idx, rates)
    assert shares.min() >= 0.0 and shares.max() <= 1.0 + 1e-6
    for c in range(N_CELLS):
        alloc = (shares[:, cell_idx == c] * cfg.n_prb).sum(axis=1)
        np.testing.assert_allclose(alloc, cfg.n_prb, rtol=1e-5)


def _check_pf_no_starvation(seed):
    """Proportional-fair never starves: every UE's share is strictly
    positive every period, and a persistently weak UE's share *grows* as
    its served average decays."""
    rng = np.random.default_rng(seed)
    n = 12
    cell_idx, _ = _random_fleet(rng, n)
    rates = rng.uniform(20.0, 130.0, (25, n))
    rates[:, 0] = 1.0  # one persistently weak UE in cell 0
    cell_idx[0] = 0
    cfg = SchedulerConfig(policy="pf")
    shares, _ = _run_steps(cfg, cell_idx, rates)
    assert np.all(shares > 0.0)
    served = (shares * rates).mean(axis=0)
    assert np.all(served > 0.0)
    # PF self-balancing: the weak UE's share rises from its cold start
    assert shares[-1, 0] > shares[0, 0]


def _check_equivariance(seed, policy):
    """Permuting the UE axis (assignment, rates, carried PF state) permutes
    the allocations — nothing in the scheduler depends on UE order."""
    rng = np.random.default_rng(seed)
    n = 14
    cell_idx, rate = _random_fleet(rng, n)
    state = scheduler_init(n)
    state = state._replace(avg_tp=state.avg_tp *
                           rng.uniform(0.5, 2.0, n).astype(np.float32))
    cfg = SchedulerConfig(policy=policy)
    perm = rng.permutation(n)
    s1, share1 = scheduler_step(cfg, N_CELLS, state, cell_idx, rate)
    s2, share2 = scheduler_step(
        cfg, N_CELLS, state._replace(avg_tp=state.avg_tp[perm]),
        cell_idx[perm], rate[perm])
    np.testing.assert_allclose(np.asarray(share2),
                               np.asarray(share1)[perm], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s2.avg_tp),
                               np.asarray(s1.avg_tp)[perm], rtol=1e-5)


if HAVE_HYPOTHESIS:
    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.given(seed=st.integers(0, 10_000),
                      policy=st.sampled_from(POLICIES))
    def test_prb_conservation(seed, policy):
        _check_conservation(seed, policy)

    @hypothesis.settings(max_examples=10, deadline=None)
    @hypothesis.given(seed=st.integers(0, 10_000))
    def test_pf_no_starvation(seed):
        _check_pf_no_starvation(seed)

    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.given(seed=st.integers(0, 10_000),
                      policy=st.sampled_from(POLICIES))
    def test_permutation_equivariance(seed, policy):
        _check_equivariance(seed, policy)
else:
    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("policy", POLICIES)
    def test_prb_conservation(seed, policy):
        _check_conservation(seed, policy)

    @pytest.mark.parametrize("seed", range(6))
    def test_pf_no_starvation(seed):
        _check_pf_no_starvation(seed)

    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("policy", POLICIES)
    def test_permutation_equivariance(seed, policy):
        _check_equivariance(seed, policy)


def test_policy_shapes():
    """rr is an equal time-share; maxsinr hands each cell's whole budget
    to its top-rate UE; pf sits strictly between at the cold start."""
    rng = np.random.default_rng(0)
    n = 9
    cell_idx = np.repeat(np.arange(N_CELLS), 3).astype(np.int32)
    rate = rng.uniform(1.0, 130.0, n)
    state = scheduler_init(n)
    _, rr = scheduler_step(SchedulerConfig("rr"), N_CELLS, state, cell_idx,
                           rate)
    _, mx = scheduler_step(SchedulerConfig("maxsinr"), N_CELLS, state,
                           cell_idx, rate)
    np.testing.assert_allclose(np.asarray(rr), 1.0 / 3.0, rtol=1e-6)
    mx = np.asarray(mx)
    for c in range(N_CELLS):
        m = cell_idx == c
        assert mx[m][np.argmax(rate[m])] == pytest.approx(1.0)
        assert np.count_nonzero(mx[m]) == 1  # distinct rates: one winner
    assert jain_index(np.asarray(rr)) > jain_index(mx)


def test_unknown_policy_rejected():
    # ValueError, not assert: config validation must survive python -O
    with pytest.raises(ValueError, match="unknown policy"):
        SchedulerConfig(policy="edf")
    with pytest.raises(ValueError, match="n_prb"):
        SchedulerConfig(n_prb=0)
    with pytest.raises(ValueError, match="pf_beta"):
        SchedulerConfig(pf_beta=1.5)


# ------------------------------------------------------- coupling layer
def test_cell_load_mean_of_attached():
    grid = np.array([[0, 0], [0, 1], [1, 1]])  # (N=3, T=2)
    demand = np.array([0.2, 0.4, 0.8])
    load = cell_load(grid, demand, n_cells=3)
    np.testing.assert_allclose(load, [[0.3, 0.2], [0.8, 0.6], [0.0, 0.0]])


def test_coupled_interference_mw_hand_computed():
    grid = np.array([[0, 0], [1, 1]])
    demand = np.array([1.0, 0.5])
    coupling = np.array([[0.0, 2.0], [4.0, 0.0]])
    extra = coupled_interference_mw(grid, demand, coupling)
    # UE0 (cell 0) sees 2.0 * load(cell1)=0.5 -> 1.0 mW; UE1 sees 4.0 * 1.0
    np.testing.assert_allclose(extra, [[1.0, 1.0], [4.0, 4.0]])


def test_ring_coupling_structure():
    c = ring_coupling(4, neighbor_dbm=-12.0, decay=0.5)
    assert np.all(np.diag(c) == 0.0)
    np.testing.assert_allclose(c[0, 1], 10 ** (-1.2))
    np.testing.assert_allclose(c[0, 2], 10 ** (-1.2) * 0.5)  # two hops
    np.testing.assert_allclose(c, c.T)


def test_coupling_raises_interference_floor_and_lowers_labels():
    """Neighbour-cell load must raise even a quiet (S0) UE's interference
    floor and depress its ground-truth throughput label."""
    n, T = 8, 6
    cell0 = attach_ring(n, 2)
    grid = np.repeat(cell0[:, None], T + sc.WINDOW, axis=1)
    scen = np.array(["none"] * n)
    loads = np.full(n, 0.9)
    off = build_cells_episode(scen, T, np.random.default_rng(9), grid, None,
                              load_ratio=loads)
    on = build_cells_episode(scen, T, np.random.default_rng(9), grid,
                             ring_coupling(2, neighbor_dbm=-5.0),
                             load_ratio=loads)
    assert np.all(off.int_dbm == -60.0)
    assert np.all(on.int_dbm > -60.0)
    assert on.tp_mbps.mean() < off.tp_mbps.mean()


def test_power_sum_dbm_linear_power():
    base = np.array([-60.0, 0.0])
    extra = np.array([10 ** (-6.0), 1.0])
    got = sc.power_sum_dbm(base, extra)
    want = 10 * np.log10(10 ** (base / 10) + extra)
    np.testing.assert_allclose(got, want)
    assert sc.power_sum_dbm(np.array([14.0]), np.array([1e3]))[0] == 14.0


def test_prb_scaled_throughput():
    tp = np.array([100.0, 50.0, 8.0])
    np.testing.assert_allclose(tpm.prb_scaled_mbps(tp, [0.5, 1.0, 0.0]),
                               [50.0, 50.0, tpm.PRB_FLOOR_MBPS])
    got = tpm.shared_throughput_mbps(np.array([-60.0]), 0.25)
    np.testing.assert_allclose(got,
                               tpm.max_throughput_mbps(np.array([-60.0]))
                               * 0.25)


# ------------------------------------------------- equivalence regression
def _fig6_like_setup():
    prof = vgg_split_profile(FULL)
    cons = Constraints(rho_max=0.92, tau_max_s=6.0, e_max_j=40.0)
    table = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2,
                           Weights(1.0, 0.15, 0.1), cons, 130)
    fixed = int(table.query(130.0))
    cfg = ControllerConfig(ewma_alpha=0.6, hysteresis_steps=2,
                           fallback_split=fixed)
    return prof, table, cfg, fixed


def test_one_cell_no_coupling_matches_engine_exactly():
    """The satellite regression: a 1-cell, coupling-off, scheduler-off
    cells fleet must reproduce ``simulate_fleet`` bit-for-bit (splits) and
    float-identically (metrics) — the scheduler hook is a no-op by
    default."""
    prof, table, cfg, fixed = _fig6_like_setup()
    n, T = 12, 10
    scen = np.asarray(sc.SCENARIOS)[np.arange(n) % 4]
    grid = np.zeros((n, T + sc.WINDOW), int)
    ep = build_cells_episode(scen, T, np.random.default_rng(11), grid, None)
    base = simulate_fleet(ep, table, prof, cfg, fixed_split=fixed)
    cell = simulate_cells(ep, grid, table, prof, cfg, sched=None,
                          fixed_split=fixed)
    assert cell.fleet.prb_share is None
    np.testing.assert_array_equal(cell.fleet.splits, base.splits)
    for f in ("est_tp", "delay_s", "privacy", "energy_j"):
        np.testing.assert_array_equal(getattr(cell.fleet, f),
                                      getattr(base, f))
        np.testing.assert_array_equal(getattr(cell.fleet.fixed, f),
                                      getattr(base.fixed, f))


def test_one_ue_per_cell_rr_matches_no_scheduler():
    """With one UE per cell every policy grants the full budget (share ==
    1.0 exactly), so the scheduled scan must reproduce the unscheduled
    engine bit-for-bit — pinning that the hook itself adds no drift."""
    prof, table, cfg, fixed = _fig6_like_setup()
    n, T = 6, 10
    scen = np.asarray(sc.SCENARIOS)[np.arange(n) % 4]
    grid = np.repeat(np.arange(n)[:, None], T + sc.WINDOW, axis=1)
    ep = build_cells_episode(scen, T, np.random.default_rng(13), grid, None)
    base = simulate_fleet(ep, table, prof, cfg, fixed_split=fixed)
    cell = simulate_cells(ep, grid, table, prof, cfg,
                          sched=SchedulerConfig(policy="rr"),
                          fixed_split=fixed)
    np.testing.assert_array_equal(cell.fleet.prb_share, 1.0)
    np.testing.assert_array_equal(cell.fleet.splits, base.splits)
    for f in ("delay_s", "privacy", "energy_j"):
        np.testing.assert_array_equal(getattr(cell.fleet, f),
                                      getattr(base, f))


# ----------------------------------------------------------- integration
def test_simulate_cells_contended_with_handover():
    """Full stack: coupling + cell handover + scheduler. Shares stay
    conserved per cell each period, contention depresses served throughput
    below the full-grant truth, and maxsinr is measurably less fair."""
    prof, table, cfg, fixed = _fig6_like_setup()
    rng = np.random.default_rng(17)
    n, T, C = 24, 12, 3
    scen = np.asarray(sc.SCENARIOS)[np.arange(n) % 4]
    grid = handover_grid(attach_ring(n, C), T + sc.WINDOW, 0.25, rng)
    ep = build_cells_episode(scen, T, rng, grid, ring_coupling(C))
    results = {}
    for pol in POLICIES:
        res = simulate_cells(ep, grid, table, prof, cfg,
                             sched=SchedulerConfig(policy=pol),
                             fixed_split=fixed)
        np.testing.assert_allclose(res.share_sums(), 1.0, rtol=1e-5)
        assert res.fleet.prb_share.shape == (n, T)
        assert np.all(res.served_mbps <= res.fleet.true_tp + 1e-9)
        results[pol] = res
    assert results["maxsinr"].jain() < results["rr"].jain()
    # the handover lands inside the report window the scheduler scans,
    # not in the KPM warm-up prefix
    rep = results["rr"].cell_idx
    assert np.any(rep[:, 0] != rep[:, -1])


def test_handover_grid_explicit_n_cells_and_warmup_default():
    """The ring modulus must come from the topology, not the occupied
    cells, and the default handover step must land past the warm-up."""
    rng = np.random.default_rng(1)
    cell0 = attach_ring(3, 4)  # cells {0,1,2} occupied, ring has 4
    grid = handover_grid(cell0, 8 + sc.WINDOW, 1.0, rng, n_cells=4)
    assert grid.max() == 3  # the UE in cell 2 wraps to cell 3, not cell 0
    changed = np.flatnonzero(grid[0] != grid[0, 0])
    assert changed.min() >= sc.WINDOW  # default t_h past the warm-up


def test_share_sums_reports_one_for_empty_cells():
    """An empty cell has no budget to conserve: share_sums() must compare
    clean against 1.0 even when a cell is unoccupied for some periods."""
    prof, table, cfg, fixed = _fig6_like_setup()
    n, T = 4, 6
    scen = np.array(["cci"] * n)
    # cell 2 of 3 never has an attached UE
    grid = np.repeat(np.array([0, 0, 1, 1])[:, None], T + sc.WINDOW, axis=1)
    ep = build_cells_episode(scen, T, np.random.default_rng(2), grid, None)
    res = simulate_cells(ep, grid, table, prof, cfg, n_cells=3,
                         sched=SchedulerConfig(policy="rr"))
    assert res.n_cells == 3
    np.testing.assert_allclose(res.share_sums(), 1.0, rtol=1e-5)
