"""Engine-level pins for the PR 7 fused report-period path + int8 serving.

Four contracts: (1) ``fused=True`` (device featurize + fused scan) tracks
the host stride-trick program through ``estimate_fleet`` and
``simulate_fleet`` — plain, scheduled, churn and online paths; (2)
``quant="int8"`` serves within 1 Mbps RMSE of the fp32 forward and is
refused under online adaptation; (3) the defaults (``quant=None,
fused=False``) are bit-identical to the PR 6 engine program; (4) the int8
replay ring adapts to drift like the fp32 ring (satellite: post-drift
RMSE within tolerance)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.channel import scenarios as sc
from repro.core.controller import ControllerConfig
from repro.core.pso import LookupTable
from repro.estimator.model import EstimatorConfig, init_estimator
from repro.models.vgg import FULL, vgg_split_profile
from repro.sim import (POLICIES, DriftConfig, OnlineConfig, SchedulerConfig,
                       estimate_fleet, online_estimate_fleet, simulate_fleet)
from repro.sim.cells import (attach_ring, build_cells_episode, handover_grid,
                             ring_coupling, simulate_cells)

N_SC_TEST = 16


def tiny_estimator(seed: int = 0):
    e = EstimatorConfig(n_sc=N_SC_TEST, lstm_hidden=8, hidden=8)
    return e, init_estimator(e, jax.random.PRNGKey(seed))


def episode(n: int, T: int = 6, seed: int = 5, **kw):
    rng = np.random.default_rng(seed)
    names = np.asarray(sc.SCENARIOS)[np.arange(n) % len(sc.SCENARIOS)]
    return sc.gen_episode_batch(names, T, rng, n_sc=N_SC_TEST, **kw)


def fig6_style_table(prof):
    return LookupTable(ue_name="t", table=np.full(41, 3, np.int32),
                       tp_min_mbps=np.zeros(len(prof.data_bytes)),
                       feasible_prefilter=np.ones(len(prof.data_bytes),
                                                  bool))


@pytest.fixture(scope="module")
def prof_table_cfg():
    prof = vgg_split_profile(FULL)
    return prof, fig6_style_table(prof), ControllerConfig(0.5, 2, 3)


# ------------------------------------------------------ estimate_fleet
def test_fused_estimate_matches_unfused():
    """The fused featurize feeds the estimator the same windows the host
    stride-trick path builds — the estimates agree to float tolerance."""
    est = tiny_estimator()
    ep = episode(8, T=5)
    a = estimate_fleet(ep, est)
    b = estimate_fleet(ep, est, fused=True)
    np.testing.assert_allclose(b, a, rtol=1e-6, atol=1e-6)


def test_fused_needs_raw_kpms():
    est = tiny_estimator()
    ep = episode(4, T=4, include_kpms=False)
    with pytest.raises(ValueError, match="raw KPM reports"):
        estimate_fleet(ep, est, fused=True)


def test_quant_mode_validated():
    est = tiny_estimator()
    ep = episode(2, T=3)
    with pytest.raises(ValueError, match="quant must be one of"):
        estimate_fleet(ep, est, quant="int4")


def test_int8_estimate_within_1mbps_of_fp32():
    """The serving-accuracy pin: int8 weights move the fleet estimate by
    well under the paper's Mbps scale (same bound the benchmark gates)."""
    est = tiny_estimator()
    ep = episode(16, T=6)
    f = estimate_fleet(ep, est)
    q = estimate_fleet(ep, est, quant="int8")
    rmse = float(np.sqrt(np.mean((q - f) ** 2)))
    assert rmse < 1.0
    # int8 composes with the fused featurize path
    qf = estimate_fleet(ep, est, quant="int8", fused=True)
    np.testing.assert_allclose(qf, q, rtol=1e-6, atol=1e-6)


# ------------------------------------------------------- simulate_fleet
def test_simulate_fleet_fused_pins(prof_table_cfg):
    prof, table, cfg = prof_table_cfg
    est = tiny_estimator()
    ep = episode(8, T=6)
    u = simulate_fleet(ep, table, prof, cfg, estimator=est)
    f = simulate_fleet(ep, table, prof, cfg, estimator=est, fused=True)
    np.testing.assert_allclose(f.est_tp, u.est_tp, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(f.splits, u.splits)
    np.testing.assert_array_equal(f.delay_s, u.delay_s)


def test_simulate_fleet_int8_close(prof_table_cfg):
    prof, table, cfg = prof_table_cfg
    est = tiny_estimator()
    ep = episode(8, T=6)
    u = simulate_fleet(ep, table, prof, cfg, estimator=est)
    q = simulate_fleet(ep, table, prof, cfg, estimator=est, quant="int8")
    rmse = float(np.sqrt(np.mean((q.est_tp - u.est_tp) ** 2)))
    assert rmse < 1.0


def test_defaults_bit_identical_to_pr6(prof_table_cfg):
    """quant=None, fused=False spelled out == the kwargs' defaults == the
    PR 6 program (the new switches are strictly opt-in)."""
    prof, table, cfg = prof_table_cfg
    est = tiny_estimator()
    ep = episode(8, T=5)
    a = simulate_fleet(ep, table, prof, cfg, estimator=est)
    b = simulate_fleet(ep, table, prof, cfg, estimator=est,
                       quant=None, fused=False)
    np.testing.assert_array_equal(a.est_tp, b.est_tp)
    np.testing.assert_array_equal(a.splits, b.splits)
    np.testing.assert_array_equal(a.energy_j, b.energy_j)


# ------------------------------------------------- scheduler / coupling
@pytest.mark.parametrize("policy", POLICIES)
def test_sched_fused_allclose(policy, prof_table_cfg):
    """SchedulerConfig(fused=True) — per-cell reductions through the
    segsum kernel — reproduces the XLA segment_sum/segment_max scan."""
    prof, table, cfg = prof_table_cfg
    est = tiny_estimator()
    rng = np.random.default_rng(2)
    n, T, n_cells = 24, 6, 3
    grid = handover_grid(attach_ring(n, n_cells), T + sc.WINDOW, 0.25, rng,
                         n_cells=n_cells)
    ep = build_cells_episode(
        np.asarray(sc.SCENARIOS)[np.arange(n) % len(sc.SCENARIOS)], T,
        rng, grid, coupling=ring_coupling(n_cells), n_sc=N_SC_TEST,
        include_iq=True)
    out = {}
    for fused in (False, True):
        scfg = SchedulerConfig(policy, pf_beta=0.3, fused=fused)
        out[fused] = simulate_cells(ep, grid, table, prof, cfg,
                                    sched=scfg, n_cells=n_cells,
                                    estimator=est)
    np.testing.assert_allclose(out[True].fleet.prb_share,
                               out[False].fleet.prb_share,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out[True].fleet.est_tp,
                               out[False].fleet.est_tp,
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(out[True].served_mbps,
                               out[False].served_mbps,
                               rtol=1e-5, atol=1e-4)


# ----------------------------------------------------------------- churn
def test_churn_fused_allclose(prof_table_cfg):
    prof, table, cfg = prof_table_cfg
    est = tiny_estimator()
    rng = np.random.default_rng(19)
    ccfg = sc.ChurnConfig(arrival_rate=2.0, mean_dwell=4.0, max_dwell=6)
    schedule = sc.make_churn_schedule(ccfg, 12, rng)
    scen = np.asarray(sc.SCENARIOS)[
        np.arange(schedule.n_sessions) % len(sc.SCENARIOS)]
    sessions = sc.gen_episode_batch(scen, schedule.max_dwell, rng,
                                    n_sc=N_SC_TEST)
    kw = dict(churn=schedule, capacity=6, estimator=est)
    u = simulate_fleet(sessions, table, prof, cfg, **kw)
    f = simulate_fleet(sessions, table, prof, cfg, fused=True, **kw)
    np.testing.assert_array_equal(f.active, u.active)
    np.testing.assert_allclose(f.est_tp, u.est_tp, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(f.splits, u.splits)
    # int8 serving through the pool: same bound as the batch path
    q = simulate_fleet(sessions, table, prof, cfg, quant="int8", **kw)
    rmse = float(np.sqrt(np.mean((q.est_tp - u.est_tp) ** 2)))
    assert rmse < 1.0


# ---------------------------------------------------------------- online
def test_online_fused_allclose(prof_table_cfg):
    """The closed loop under the fused featurize path: same adaptation
    schedule, estimates allclose (the ring ingests identical windows)."""
    prof, table, cfg = prof_table_cfg
    est = tiny_estimator()
    ep = episode(8, T=8)
    ocfg = OnlineConfig(capacity=64, batch=16, steps=4, min_fill=8,
                        drift=DriftConfig(calibrate_periods=2,
                                          threshold_mbps=0.0, patience=1,
                                          cooldown=1))
    u = simulate_fleet(ep, table, prof, cfg, estimator=est, online=ocfg)
    f = simulate_fleet(ep, table, prof, cfg, estimator=est, online=ocfg,
                       fused=True)
    np.testing.assert_array_equal(f.online.adapted, u.online.adapted)
    assert f.online.n_adaptations == u.online.n_adaptations > 0
    np.testing.assert_allclose(f.est_tp, u.est_tp, rtol=1e-4, atol=1e-3)


def test_online_refuses_int8_serving(prof_table_cfg):
    prof, table, cfg = prof_table_cfg
    est = tiny_estimator()
    ep = episode(4, T=4)
    with pytest.raises(ValueError, match="frozen estimator"):
        simulate_fleet(ep, table, prof, cfg, estimator=est,
                       online=OnlineConfig(), quant="int8")


def test_int8_ring_adapts_like_fp32_ring():
    """Satellite pin: the quantized replay ring closes the same drift the
    fp32 ring does — identical adaptation schedule (the trigger cadence is
    label-driven, not storage-driven) and post-drift RMSE within
    tolerance, both beating the frozen estimator."""
    e, params = tiny_estimator()
    ep = episode(16, T=16, seed=9)
    base = OnlineConfig(capacity=256, batch=64, steps=10, lr=3e-3,
                        min_fill=16, seed=1,
                        drift=DriftConfig(calibrate_periods=2,
                                          threshold_mbps=0.0, patience=1,
                                          cooldown=1))
    frozen = estimate_fleet(ep, (e, params))
    est_f, st_f = online_estimate_fleet(ep, (e, params), base)
    est_q, st_q = online_estimate_fleet(
        ep, (e, params), dataclasses.replace(base, ring_quant="int8"))
    np.testing.assert_array_equal(st_q.adapted, st_f.adapted)
    assert st_q.n_adaptations == st_f.n_adaptations > 0
    tp = np.asarray(ep.tp_mbps, float)
    late = slice(ep.n_steps // 2, None)

    def rmse(x):
        return float(np.sqrt(np.mean((x[:, late] - tp[:, late]) ** 2)))

    r_f, r_q, r_z = rmse(est_f), rmse(est_q), rmse(frozen)
    assert r_q < r_z and r_f < r_z  # both rings actually adapt
    # quantized replay costs at most a modest accuracy margin
    assert abs(r_q - r_f) < max(2.0, 0.25 * r_f)
