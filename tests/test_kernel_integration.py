"""Model-level integration of Pallas kernels: forward with
USE_PALLAS_ATTENTION must match the default XLA paths (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import blocks, init_params
from repro.models.lm import forward


@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-370m"])
def test_model_forward_with_pallas_kernels(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    ref, _, _ = forward(cfg, params, batch, mode="train", remat="none")
    blocks.USE_PALLAS_ATTENTION = True
    try:
        got, _, _ = forward(cfg, params, batch, mode="train", remat="none")
    finally:
        blocks.USE_PALLAS_ATTENTION = False
    d = np.abs(np.asarray(got, np.float32) - np.asarray(ref, np.float32))
    scale = np.abs(np.asarray(ref, np.float32)).max()
    # bf16 accumulation-order noise: bound relative to the logit scale
    assert d.max() <= 0.05 * scale, (d.max(), scale)


def test_pallas_attention_grad_path():
    """The kernel path is differentiable in interpret mode (bwd recomputes
    through the pallas call)."""
    cfg = get_config("granite-8b").reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (1, 16), 0, cfg.vocab),
             "labels": jax.random.randint(key, (1, 16), 0, cfg.vocab)}
    from repro.models.lm import lm_loss

    blocks.USE_PALLAS_ATTENTION = True
    try:
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch, remat="none")[0])(params)
    finally:
        blocks.USE_PALLAS_ATTENTION = False
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g, np.float32)).all()
               for g in jax.tree.leaves(grads))
