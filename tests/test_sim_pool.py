"""Slot-pool engine tests: pool invariants (no double-assigned slot,
free-list conservation, masked PRB conservation), the churn-disabled
bit-identity pin against the batch engine, full-pool equivalence, the
scan-vs-stepwise equality, lifecycle accounting, and the online
composition. Property tests run through hypothesis when available,
otherwise a fixed-seed sweep of the same checks (the suite's standard
pattern)."""
try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel import scenarios as sc
from repro.core.controller import ControllerConfig
from repro.core.energy import EDGE_A40X2, UE_VM_2CORE
from repro.core.objective import Constraints, Weights
from repro.core.pso import NO_SPLIT, pso_vectorized
from repro.models.vgg import FULL, vgg_split_profile
from repro.sim import (POLICIES, SchedulerConfig, scheduler_init,
                       scheduler_step, simulate_fleet, simulate_pool)
from repro.sim.pool import PoolState, pool_init, pool_programs

I32 = jnp.int32


@pytest.fixture(scope="module")
def prof_table_cfg():
    prof = vgg_split_profile(FULL)
    cons = Constraints(rho_max=0.92, tau_max_s=6.0, e_max_j=40.0)
    table = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2,
                           Weights(1.0, 0.15, 0.1), cons, 130)
    cfg = ControllerConfig(ewma_alpha=0.6, hysteresis_steps=2,
                           fallback_split=int(table.query(130.0)))
    return prof, table, cfg


def _schedule(rng, T, rate, dwell, max_dwell):
    ccfg = sc.ChurnConfig(arrival_rate=rate, mean_dwell=dwell,
                          max_dwell=max_dwell)
    schedule = sc.make_churn_schedule(ccfg, T, rng)
    if schedule.n_sessions == 0:  # pragma: no cover - rate keeps M > 0
        pytest.skip("empty arrival realisation")
    return schedule


def _sessions(rng, schedule):
    scen = np.asarray(sc.SCENARIOS)[
        np.arange(schedule.n_sessions) % len(sc.SCENARIOS)]
    return sc.gen_episode_batch(scen, schedule.max_dwell, rng,
                                include_iq=False, include_kpms=False)


def _full_pool_schedule(n, T):
    """Every session arrives at t=0 and dwells the whole horizon."""
    return sc.ChurnSchedule(arrival_t=np.zeros(n, np.int32),
                            dwell=np.full(n, T, np.int32),
                            ready_end=np.full(T, n, np.int32),
                            horizon=T, max_admits=n)


# ------------------------------------------------------- pool invariants
def _drive_pool(seed, capacity, T=25, rate=3.0, dwell=4.0):
    """Step the pool period by period through the jitted admit/serve
    programs, checking the slot invariants after every sub-step."""
    rng = np.random.default_rng(seed)
    schedule = _schedule(rng, T, rate, dwell, max_dwell=8)
    sessions = _sessions(rng, schedule)
    true_d = jnp.asarray(np.asarray(sessions.tp_mbps, np.float32))
    m = schedule.n_sessions
    tables_d = jnp.asarray(
        np.zeros((1, 131), np.int32))  # all-NO_SPLIT shared row
    cell_d = jnp.zeros(m, I32)
    dwell_d = jnp.asarray(schedule.dwell, I32)
    arrival_d = jnp.asarray(schedule.arrival_t, I32)
    programs = pool_programs(0.5, 2, 3, None, 1, int(schedule.max_admits))
    st = pool_init(capacity, warm_split=3)

    def check(st: PoolState, where: str):
        act = np.asarray(st.active)
        free = np.asarray(st.free)
        n_free = int(st.n_free)
        # free-list conservation: every slot is active XOR on the stack
        assert n_free + act.sum() == capacity, where
        stack = free[:n_free]
        assert len(np.unique(stack)) == n_free, f"{where}: stack dup"
        assert not act[stack].any(), f"{where}: active slot on free stack"
        # no double-assigned slot: live sids are unique
        sids = np.asarray(st.sid)[act]
        assert len(np.unique(sids)) == len(sids), f"{where}: sid dup"
        return act, sids

    admitted = set()
    for t in range(T):
        st, lat = programs.admit(st, jnp.asarray(t, I32),
                                 jnp.asarray(int(schedule.ready_end[t]), I32),
                                 arrival_d, jnp.asarray(3, I32))
        act, sids = check(st, f"after admit t={t}")
        lat = np.asarray(lat)
        # admission lanes: valid lanes are a prefix, latencies non-negative
        valid = lat >= 0
        if valid.any():
            assert valid[:valid.sum()].all()
        # a session is admitted at most once, in FIFO order
        for s in sids:
            admitted.add(int(s))
        assert int(st.next_arrival) == len(admitted)
        assert int(st.next_arrival) <= int(schedule.ready_end[t])
        st, ys = programs.serve_retire(st, tables_d,
                                       jnp.zeros(capacity, jnp.float32),
                                       true_d, cell_d, dwell_d)
        check(st, f"after retire t={t}")
        # ages of live sessions never exceed their dwell
        act = np.asarray(st.active)
        ages = np.asarray(st.age)[act]
        dws = schedule.dwell[np.asarray(st.sid)[act]]
        assert (ages < dws).all()


def _check_masked_conservation(seed, policy):
    """Masked scheduler_step: active slots' shares sum to 1 per non-empty
    cell, inactive slots get exactly 0, and the active=None path is
    untouched by the mask machinery (all-active mask matches it)."""
    rng = np.random.default_rng(seed)
    n, n_cells = 17, 3
    cell_idx = np.concatenate([np.arange(n_cells),
                               rng.integers(0, n_cells, n - n_cells)])
    rate = rng.uniform(0.5, 130.0, n).astype(np.float32)
    active = rng.random(n) < 0.6
    cfg = SchedulerConfig(policy=policy)
    state = scheduler_init(n)
    _, share = scheduler_step(cfg, n_cells, state, cell_idx, rate,
                              active=active)
    share = np.asarray(share)
    assert (share[~active] == 0.0).all()
    assert (share >= 0.0).all() and (share <= 1.0 + 1e-6).all()
    for c in range(n_cells):
        m = active & (cell_idx == c)
        if m.any():
            assert share[m].sum() == pytest.approx(1.0, rel=1e-5)
    # all-active mask == no mask (the fixed-fleet arm), down to float
    s1, sh1 = scheduler_step(cfg, n_cells, state, cell_idx, rate)
    s2, sh2 = scheduler_step(cfg, n_cells, state, cell_idx, rate,
                             active=np.ones(n, bool))
    np.testing.assert_allclose(np.asarray(sh2), np.asarray(sh1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s2.avg_tp), np.asarray(s1.avg_tp),
                               rtol=1e-6)


if HAVE_HYPOTHESIS:
    @hypothesis.settings(max_examples=8, deadline=None)
    @hypothesis.given(seed=st.integers(0, 10_000),
                      capacity=st.integers(4, 24))
    def test_pool_invariants(seed, capacity):
        _drive_pool(seed, capacity)

    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.given(seed=st.integers(0, 10_000),
                      policy=st.sampled_from(POLICIES))
    def test_masked_prb_conservation(seed, policy):
        _check_masked_conservation(seed, policy)
else:
    @pytest.mark.parametrize("seed,capacity", [(0, 4), (1, 9), (2, 16),
                                               (3, 24)])
    def test_pool_invariants(seed, capacity):
        _drive_pool(seed, capacity)

    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("policy", POLICIES)
    def test_masked_prb_conservation(seed, policy):
        _check_masked_conservation(seed, policy)


def test_jain_index_masked():
    """Fairness over the live population only: empty slots must not make
    a half-occupied pool look unfair, and an empty pool is vacuously
    fair."""
    from repro.sim import jain_index
    x = np.array([5.0, 0.0, 5.0, 0.0])
    act = np.array([True, False, True, False])
    assert jain_index(x) == pytest.approx(0.5)
    assert jain_index(x, active=act) == pytest.approx(1.0)
    assert jain_index(x, active=np.zeros(4, bool)) == 1.0
    assert jain_index(x[act]) == jain_index(x, active=act)


# --------------------------------------------------- equivalence pins
def test_churn_disabled_bit_identity(prof_table_cfg):
    """churn=None must BE the batch engine: the pool module is never
    imported and splits/metrics come out of the exact same arrays."""
    prof, table, cfg = prof_table_cfg
    rng = np.random.default_rng(2)
    scen = np.asarray(sc.SCENARIOS)[np.arange(8) % 4]
    ep = sc.gen_episode_batch(scen, 12, rng, include_iq=False)
    a = simulate_fleet(ep, table, prof, cfg, fixed_split=3)
    b = simulate_fleet(ep, table, prof, cfg, fixed_split=3, churn=None)
    np.testing.assert_array_equal(a.splits, b.splits)
    for f in ("true_tp", "est_tp", "delay_s", "privacy", "energy_j"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    assert b.active is None and b.lifecycle is None


@pytest.mark.parametrize("policy", [None, "rr", "pf", "maxsinr"])
def test_full_pool_matches_batch_engine(prof_table_cfg, policy):
    """Degenerate churn (all sessions at t=0, dwell = horizon, capacity =
    sessions) through the pool == the batch engine: bit-identical splits,
    float-identical metrics — for every scheduler policy."""
    prof, table, cfg = prof_table_cfg
    rng = np.random.default_rng(3)
    n, T, n_cells = 8, 15, 3
    scen = np.asarray(sc.SCENARIOS)[np.arange(n) % 4]
    ep = sc.gen_episode_batch(scen, T, rng, include_iq=False)
    schedule = _full_pool_schedule(n, T)
    if policy is None:
        base = simulate_fleet(ep, table, prof, cfg, fixed_split=3)
        pool = simulate_fleet(ep, table, prof, cfg, fixed_split=3,
                              churn=schedule, capacity=n)
    else:
        cell = np.arange(n) % n_cells
        grid = np.repeat(cell[:, None], T, axis=1)
        scfg = SchedulerConfig(policy, pf_beta=0.3)
        base = simulate_fleet(ep, table, prof, cfg, sched=scfg,
                              cell_idx=grid, n_cells=n_cells)
        pool = simulate_fleet(ep, table, prof, cfg, sched=scfg,
                              cell_idx=cell, n_cells=n_cells,
                              churn=schedule, capacity=n)
    assert pool.active.all()
    np.testing.assert_array_equal(base.splits, pool.splits)
    # PF shares can differ by 1 ULP (different XLA fusion of the masked
    # weight product); every other policy is bit-identical in practice
    for f in ("true_tp", "est_tp", "delay_s", "privacy", "energy_j"):
        np.testing.assert_allclose(getattr(base, f), getattr(pool, f),
                                   rtol=1e-5)
    lc = pool.lifecycle
    assert lc.n_admitted == n and (lc.admit_latency == 0).all()
    assert (lc.occupancy == n).all()
    assert lc.departed.sum() == n  # everyone retires at the horizon


def test_pool_scan_matches_stepwise(prof_table_cfg):
    """The fused scan sweep == the admit/serve_retire host loop, bit for
    bit: the online path's driver is the same program, just unrolled."""
    prof, table, cfg = prof_table_cfg
    rng = np.random.default_rng(7)
    T, capacity = 20, 8
    schedule = _schedule(rng, T, rate=2.0, dwell=4.0, max_dwell=8)
    sessions = _sessions(rng, schedule)
    res = simulate_pool(sessions, schedule, table, prof, cfg,
                        capacity=capacity)
    programs = pool_programs(cfg.ewma_alpha, cfg.hysteresis_steps,
                             cfg.fallback_split, None, 1,
                             int(schedule.max_admits))
    m = schedule.n_sessions
    true_np = np.asarray(sessions.tp_mbps, np.float32)
    true_d = jnp.asarray(true_np)
    tables_d = jnp.asarray(np.broadcast_to(
        table.table, (1, len(table.table))).astype(np.int32))
    st = pool_init(capacity, warm_split=cfg.fallback_split)
    splits, actives = [], []
    for t in range(T):
        st, _ = programs.admit(st, jnp.asarray(t, I32),
                               jnp.asarray(int(schedule.ready_end[t]), I32),
                               jnp.asarray(schedule.arrival_t, I32),
                               jnp.asarray(cfg.fallback_split, I32))
        # gather the frozen estimates exactly as the scan body does
        sid = np.clip(np.asarray(st.sid), 0, m - 1)
        age = np.clip(np.asarray(st.age), 0, sessions.n_steps - 1)
        est_t = np.where(np.asarray(st.active), true_np[sid, age], 0.0)
        st, ys = programs.serve_retire(st, tables_d,
                                       jnp.asarray(est_t, jnp.float32),
                                       true_d, jnp.zeros(m, I32),
                                       jnp.asarray(schedule.dwell, I32))
        actives.append(np.asarray(ys[0]))
        splits.append(np.asarray(ys[3]))
    np.testing.assert_array_equal(res.splits, np.stack(splits).T)
    np.testing.assert_array_equal(res.active, np.stack(actives).T)


def test_pool_lifecycle_accounting(prof_table_cfg):
    """Admissions - departures = final occupancy; inactive cells carry
    NaN metrics and NO_SPLIT; occupancy never exceeds capacity; admission
    latency matches the FIFO backlog."""
    prof, table, cfg = prof_table_cfg
    rng = np.random.default_rng(11)
    T, capacity = 30, 6
    schedule = _schedule(rng, T, rate=4.0, dwell=6.0, max_dwell=10)
    sessions = _sessions(rng, schedule)
    res = simulate_pool(sessions, schedule, table, prof, cfg,
                        capacity=capacity, fixed_split=3)
    lc = res.lifecycle
    assert (lc.occupancy <= capacity).all()
    assert lc.n_admitted <= lc.n_sessions
    assert lc.ue_steps == res.active.sum() == lc.occupancy.sum()
    # occupancy[t] is snapshotted after period t's admissions but before
    # its departures, so only departures from earlier periods are gone
    dep_before = np.concatenate([[0], lc.departed[:-1].cumsum()])
    assert (lc.admitted.cumsum() - dep_before == lc.occupancy).all()
    assert (lc.admit_latency >= 0).all()
    assert lc.admit_latency.shape == (lc.n_admitted,)
    assert lc.p99_admit_latency() >= 0.0
    act = res.active
    assert np.isfinite(res.delay_s[act]).all()
    assert np.isnan(res.delay_s[~act]).all()
    assert (res.splits[~act] == NO_SPLIT).all()
    assert (res.true_tp[~act] == 0.0).all()
    assert np.isnan(res.fixed.delay_s[~act]).all()
    # a saturated pool queues: with rate*dwell >> capacity some session
    # must wait, and FIFO order means latencies are bounded by the horizon
    assert (lc.admit_latency < T).all()


def test_pool_online_composes(prof_table_cfg):
    """The online arm drives the same slot pool (admission + masked
    ingestion + serve) and produces the adaptation trace."""
    from repro.estimator.model import EstimatorConfig, init_estimator
    import jax

    prof, table, cfg = prof_table_cfg
    rng = np.random.default_rng(19)
    T, capacity = 12, 6
    schedule = _schedule(rng, T, rate=2.0, dwell=4.0, max_dwell=6)
    scen = np.asarray(sc.SCENARIOS)[
        np.arange(schedule.n_sessions) % len(sc.SCENARIOS)]
    sessions = sc.gen_episode_batch(scen, schedule.max_dwell, rng,
                                    include_iq=True, n_sc=16)
    e = EstimatorConfig(n_sc=16, lstm_hidden=8, hidden=8)
    params = init_estimator(e, jax.random.PRNGKey(0))
    from repro.sim import DriftConfig, OnlineConfig
    ocfg = OnlineConfig(capacity=64, batch=8, steps=2, min_fill=8,
                        drift=DriftConfig(calibrate_periods=2,
                                          threshold_mbps=0.0, patience=1,
                                          cooldown=1))
    res = simulate_fleet(sessions, table, prof, cfg, churn=schedule,
                         capacity=capacity, estimator=(e, params),
                         online=ocfg)
    assert res.online is not None
    assert res.online.rmse.shape == (T,)
    assert res.online.n_adaptations > 0
    assert res.active.shape == (capacity, T)
    # estimates exist exactly on active cells (clipped >= 1 Mbps there)
    assert (res.est_tp[~res.active] == 0.0).all()
    assert (res.est_tp[res.active] >= 1.0).all()
    # ring ingested only active-slot samples
    assert res.online.buffer_fill <= min(64, int(res.active.sum()))


def test_pool_online_needs_room_for_slots(prof_table_cfg):
    """Masked ingestion requires ring capacity >= pool capacity."""
    from repro.estimator.model import EstimatorConfig, init_estimator
    import jax

    prof, table, cfg = prof_table_cfg
    rng = np.random.default_rng(23)
    schedule = _schedule(rng, 8, rate=2.0, dwell=3.0, max_dwell=4)
    scen = np.asarray(sc.SCENARIOS)[
        np.arange(schedule.n_sessions) % len(sc.SCENARIOS)]
    sessions = sc.gen_episode_batch(scen, schedule.max_dwell, rng,
                                    include_iq=True, n_sc=16)
    e = EstimatorConfig(n_sc=16, lstm_hidden=8, hidden=8)
    params = init_estimator(e, jax.random.PRNGKey(0))
    from repro.sim import OnlineConfig
    with pytest.raises(ValueError, match="cover the pool"):
        simulate_fleet(sessions, table, prof, cfg, churn=schedule,
                       capacity=32, estimator=(e, params),
                       online=OnlineConfig(capacity=16))


# ----------------------------------------------------------- validation
def test_pool_validation_raises(prof_table_cfg):
    prof, table, cfg = prof_table_cfg
    rng = np.random.default_rng(29)
    schedule = _schedule(rng, 10, rate=2.0, dwell=3.0, max_dwell=5)
    sessions = _sessions(rng, schedule)
    with pytest.raises(TypeError, match="capacity"):
        simulate_fleet(sessions, table, prof, cfg, churn=schedule)
    with pytest.raises(ValueError, match="capacity"):
        simulate_pool(sessions, schedule, table, prof, cfg, capacity=0)
    bad = sc.gen_episode_batch(["none"] * (schedule.n_sessions + 1),
                               schedule.max_dwell, rng,
                               include_iq=False, include_kpms=False)
    with pytest.raises(ValueError, match="session rows"):
        simulate_pool(bad, schedule, table, prof, cfg, capacity=4)
    with pytest.raises(ValueError, match="cell"):
        simulate_pool(sessions, schedule, table, prof, cfg, capacity=4,
                      sched=SchedulerConfig("rr"))
    short = sc.gen_episode_batch(
        ["none"] * schedule.n_sessions, max(schedule.max_dwell - 1, 1),
        rng, include_iq=False, include_kpms=False)
    if schedule.max_dwell > 1:
        with pytest.raises(ValueError, match="dwell"):
            simulate_pool(short, schedule, table, prof, cfg, capacity=4)
    with pytest.raises(ValueError, match="needs an estimator"):
        from repro.sim import OnlineConfig
        simulate_pool(sessions, schedule, table, prof, cfg, capacity=4,
                      online=OnlineConfig())


def test_churn_config_validation():
    with pytest.raises(ValueError, match="arrival_rate"):
        sc.ChurnConfig(arrival_rate=-1.0)
    with pytest.raises(ValueError, match="diurnal_amplitude"):
        sc.ChurnConfig(diurnal_amplitude=2.0)
    with pytest.raises(ValueError, match="mean_dwell"):
        sc.ChurnConfig(mean_dwell=0.5)


def test_diurnal_rate_modulation():
    """The diurnal tide modulates the Poisson rate around the mean and
    never goes negative."""
    cfg = sc.ChurnConfig(arrival_rate=10.0, diurnal_amplitude=1.0,
                         diurnal_period=20)
    lam = sc.diurnal_arrival_rate(cfg, 40)
    assert lam.shape == (40,)
    assert (lam >= 0.0).all()
    assert lam.max() == pytest.approx(20.0, rel=1e-6)
    flat = sc.diurnal_arrival_rate(sc.ChurnConfig(arrival_rate=3.0), 10)
    np.testing.assert_allclose(flat, 3.0)


def test_lean_episode_generation():
    """include_kpms=False skips report synthesis; the windows accessor
    then refuses instead of crashing downstream."""
    rng = np.random.default_rng(0)
    ep = sc.gen_episode_batch(["none", "cci"], 5, rng, include_iq=False,
                              include_kpms=False)
    assert ep.kpms is None and ep.iq is None
    assert ep.tp_mbps.shape == (2, 5)
    with pytest.raises(ValueError, match="include_kpms"):
        ep.kpm_windows()
