"""Channel simulator + throughput estimator tests (reduced IQ width)."""
import numpy as np
import pytest

from repro.channel import iq as iqmod
from repro.channel import kpm as kpmmod
from repro.channel import scenarios as sc
from repro.channel import throughput as tp
from repro.estimator.baselines import (constant_floor, mlp_fit_predict,
                                       persistence_rmse, ridge_fit,
                                       ridge_predict, summary_features)
from repro.estimator.model import EstimatorConfig, estimator_forward, init_estimator
from repro.estimator.train import (BATCH_KEYS, make_train_step, r2_rmse,
                                   train_estimator)

N_SC_TEST = 144  # reduced spectrogram height for CPU tests


def test_throughput_decreasing_in_interference():
    """Weak monotonicity: TPC may locally over-compensate by <0.5 Mbps, but
    the trend across zones is strictly downward."""
    xs = np.linspace(-60, 14, 200)
    y = tp.max_throughput_mbps(xs)
    assert np.all(np.diff(y) <= 0.5)
    assert y[0] == pytest.approx(tp.PEAK_MBPS, rel=0.05)
    zones = tp.max_throughput_mbps(np.array([-60.0, -10.0, 5.0, 12.0]))
    assert np.all(np.diff(zones) < 0)
    assert y[-1] < 6.0


def test_zone_model_fig2a():
    """High-load KPM behaviour per zone: TPC ramps in Power-Control, MCS
    drops in MCS-Control, BLER saturates in OOC."""
    assert tp.tpc_boost_db(np.array(-30.0)) == 0.0
    assert tp.tpc_boost_db(np.array(-6.0)) > 10.0
    assert tp.mcs_index(np.array(-25.0)) == 28
    assert tp.mcs_index(np.array(7.0)) <= 3
    assert tp.bler(np.array(-10.0)) == pytest.approx(0.1, abs=0.02)
    assert tp.bler(np.array(12.0)) > 0.9


def test_low_load_kpms_blind_to_interference():
    """The paper's Fig. 2b observation: at low UL load the numerical KPMs
    barely move while max achievable throughput collapses."""
    rng = np.random.default_rng(0)
    quiet = kpmmod.kpm_window(np.full(64, -60.0), 0.1, rng)
    jammed = kpmmod.kpm_window(np.full(64, 5.0), 0.1, rng)
    i_mcs = kpmmod.KPMS_15.index("ul_mcs")
    i_tpc = kpmmod.KPMS_15.index("tpc")
    assert abs(quiet[:, i_mcs].mean() - jammed[:, i_mcs].mean()) < 2.0
    assert abs(quiet[:, i_tpc].mean() - jammed[:, i_tpc].mean()) < 2.0
    tq = tp.max_throughput_mbps(np.array(-60.0))
    tj = tp.max_throughput_mbps(np.array(5.0))
    assert tj < 0.5 * tq


def test_high_load_kpms_see_interference():
    rng = np.random.default_rng(1)
    quiet = kpmmod.kpm_window(np.full(64, -60.0), 0.95, rng)
    jammed = kpmmod.kpm_window(np.full(64, 5.0), 0.95, rng)
    i_mcs = kpmmod.KPMS_15.index("ul_mcs")
    assert quiet[:, i_mcs].mean() - jammed[:, i_mcs].mean() > 10.0


def test_spectrogram_reveals_interference_at_low_load():
    rng = np.random.default_rng(2)
    a = iqmod.spectrogram(-60.0, "none", 0.1, rng, n_sc=N_SC_TEST)
    b = iqmod.spectrogram(5.0, "jamming", 0.1, rng, n_sc=N_SC_TEST)
    assert b.shape == (2, N_SC_TEST, 14)
    assert (b**2).mean() > 5 * (a**2).mean()


@pytest.mark.parametrize("scen", sc.SCENARIOS)
def test_episode_generation(scen):
    rng = np.random.default_rng(3)
    eps = sc.gen_episode(scen, 5, rng, n_sc=N_SC_TEST)
    assert len(eps) == 5
    s = eps[0]
    assert s.kpms.shape == (sc.WINDOW, 15)
    assert s.iq.shape == (2, N_SC_TEST, 14)
    assert 0.5 <= s.tp_mbps <= tp.PEAK_MBPS + 1


def test_estimator_forward_and_training_reduces_loss():
    e = EstimatorConfig(n_sc=N_SC_TEST, lstm_hidden=32, hidden=32)
    rng = np.random.default_rng(4)
    data = sc.gen_dataset(30, rng, episode_len=10, n_sc=N_SC_TEST)
    import jax
    params = init_estimator(e, jax.random.PRNGKey(0))
    pred = estimator_forward(e, params, data["kpms"][:4], data["iq"][:4],
                             data["alloc"][:4])
    assert pred.shape == (4,)
    params, hist, _ = train_estimator(e, data, steps=60, batch=16,
                                      log_every=20)
    assert hist[-1][1] < hist[0][1] * 0.8


def test_device_resident_loop_matches_explicit_batches():
    """The offline loop keeps the dataset device-resident and gathers each
    minibatch by index inside the jitted step; at equal seeds its loss
    trajectory and final params must match the explicit host-sliced
    minibatch path (the pre-refactor loop) bit for bit."""
    import jax
    import jax.numpy as jnp
    from repro.optim import AdamW

    e = EstimatorConfig(n_sc=16, lstm_hidden=8, hidden=8)
    rng = np.random.default_rng(6)
    data = sc.gen_dataset(20, rng, episode_len=5, n_sc=16)
    seed, steps, batch, lr = 3, 12, 8, 1e-3
    # reference: the old loop, verbatim — host-sliced minibatches through
    # the explicit-batch step, same rng/key streams
    key = jax.random.PRNGKey(seed)
    from repro.estimator.model import init_estimator as init
    params = init(e, key)
    opt = AdamW(lr=lr, weight_decay=1e-4, clip_norm=1.0)
    opt_state = opt.init(params)
    step_fn = make_train_step(e, opt)
    n = len(data["tp"])
    hrng = np.random.default_rng(seed)
    ref_losses = []
    for _ in range(steps):
        idx = hrng.integers(0, n, batch)
        mb = {k: jnp.asarray(v[idx]) for k, v in data.items()
              if k in BATCH_KEYS}
        key, sub = jax.random.split(key)
        params, opt_state, loss = step_fn(params, opt_state, mb, sub)
        ref_losses.append(float(loss))
    got_params, hist, _ = train_estimator(e, data, steps=steps, batch=batch,
                                          lr=lr, seed=seed, log_every=1)
    np.testing.assert_allclose([l for _, l in hist], ref_losses, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_constant_floor_is_train_mean_rmse():
    """The floor is exactly the RMSE of predicting the train mean —
    zero when the test set IS that constant, analytic on a known split."""
    ytr = np.array([10.0, 20.0, 30.0])  # mean 20
    assert constant_floor(ytr, np.full(5, 20.0)) == 0.0
    yte = np.array([10.0, 30.0])
    assert constant_floor(ytr, yte) == pytest.approx(10.0)
    # scale-invariance sanity: a wider test spread raises the floor
    assert constant_floor(ytr, np.array([0.0, 40.0])) > 10.0


def test_persistence_rmse_analytic_and_guards():
    """est_t = tp_{t-h}: exact on a linear ramp (|diff| == slope * h),
    zero on a constant trace, and the horizon guard survives python -O."""
    ramp = np.arange(10.0)[None].repeat(3, 0)  # slope 1
    assert persistence_rmse(ramp, horizon=1) == pytest.approx(1.0)
    assert persistence_rmse(ramp, horizon=3) == pytest.approx(3.0)
    assert persistence_rmse(np.full((2, 6), 7.0)) == 0.0
    for bad in (0, 10, -1):
        with pytest.raises(ValueError, match="horizon"):
            persistence_rmse(ramp, horizon=bad)


def test_learned_baselines_beat_constant_floor():
    """Table II only means something above the floor: ridge and the MLP
    on the same summary features must both beat the train-mean constant
    predictor on a held-out set."""
    rng = np.random.default_rng(7)
    tr = sc.gen_dataset(40, rng, episode_len=8, n_sc=16)
    te = sc.gen_dataset(15, rng, episode_len=6, n_sc=16)
    floor = constant_floor(tr["tp"], te["tp"])
    X_tr = summary_features(tr["kpms"], "kpm15")
    X_te = summary_features(te["kpms"], "kpm15")
    w = ridge_fit(X_tr, tr["tp"])
    _, rmse_ridge = r2_rmse(ridge_predict(w, X_te), te["tp"])
    pred_mlp = mlp_fit_predict(X_tr, tr["tp"], X_te, steps=200)
    _, rmse_mlp = r2_rmse(pred_mlp, te["tp"])
    assert rmse_ridge < floor
    assert rmse_mlp < floor


def test_iq_features_beat_kpm_only_at_low_load():
    """Miniature Table II: ridge on 7 KPMs < ridge on 15 KPMs (ties under
    pure low-load) << IQ-aware estimator. Low-load regime only."""
    e = EstimatorConfig(n_sc=N_SC_TEST, lstm_hidden=32, hidden=32)
    rng = np.random.default_rng(5)
    tr = sc.gen_dataset(60, rng, episode_len=12, low_load_only=True,
                        n_sc=N_SC_TEST)
    te = sc.gen_dataset(20, rng, episode_len=6, low_load_only=True,
                        n_sc=N_SC_TEST)
    r2s = {}
    for fs in ("kpm7", "kpm15"):
        w = ridge_fit(summary_features(tr["kpms"], fs), tr["tp"])
        r2s[fs], _ = r2_rmse(ridge_predict(w, summary_features(te["kpms"], fs)),
                             te["tp"])
    params, _, (r2_iq, _) = train_estimator(e, tr, steps=250, batch=24,
                                            eval_data=te, log_every=100)
    assert r2_iq > r2s["kpm15"] - 0.02
    assert r2_iq > r2s["kpm7"]
    assert r2_iq > 0.5
