#!/usr/bin/env python
"""Render run health from a telemetry record (``repro.sim.telemetry``).

Reads the JSON a ``benchmarks/fleet.py --telemetry --json`` run commits
(or any dict with a ``TelemetryRecord.to_dict()`` payload under
``telemetry.record``) and prints the run-health summary an operator
would want first: occupancy over time, estimator RMSE, the drift /
adaptation event timeline, admission-latency percentiles and the metric
histograms — all from the committed artifact, no simulator import, no
jax.

Usage: python tools/fleetmon.py [benchmarks/results/telemetry_smoke.json]
"""
from __future__ import annotations

import json
import pathlib
import sys

DEFAULT = (pathlib.Path(__file__).resolve().parents[1]
           / "benchmarks" / "results" / "telemetry_smoke.json")
BARS = " ▁▂▃▄▅▆▇█"


def sparkline(values, width: int = 60) -> str:
    """Unicode sparkline of a series (downsampled to ``width`` points)."""
    vals = [float(v) for v in values]
    if not vals:
        return "(empty)"
    if len(vals) > width:
        stride = len(vals) / width
        vals = [vals[int(i * stride)] for i in range(width)]
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    return "".join(BARS[1 + int((v - lo) / span * (len(BARS) - 2))]
                   for v in vals)


def hbar(count: int, total: int, width: int = 40) -> str:
    n = 0 if total <= 0 else int(round(width * count / total))
    return "#" * n


def percentile(sorted_vals, q: float) -> float:
    """Nearest-rank percentile of an ascending list (no numpy needed)."""
    if not sorted_vals:
        return float("nan")
    idx = min(len(sorted_vals) - 1,
              max(0, int(round(q / 100.0 * (len(sorted_vals) - 1)))))
    return float(sorted_vals[idx])


def load_record(path: pathlib.Path) -> dict:
    payload = json.loads(path.read_text())
    # accept the benchmark JSON ({"telemetry": {"record": ...}}), a bare
    # {"record": ...} wrapper, or the record dict itself
    for key in ("telemetry", "record"):
        if isinstance(payload, dict) and key in payload:
            payload = payload[key]
    if "record" in payload:
        payload = payload["record"]
    if "events" not in payload or "series" not in payload:
        raise SystemExit(f"{path}: no telemetry record found")
    return payload


def render(rec: dict) -> str:
    lines = []
    periods = rec["periods"]
    active = rec["active_steps"]
    lines.append(f"periods={periods}  active_ue_steps={active}  "
                 f"admitted={rec['admitted']}  departed={rec['departed']}  "
                 f"handovers={rec['handovers']}")
    if rec.get("dropped_events"):
        lines.append(f"WARNING: event ring overflowed — "
                     f"{rec['dropped_events']} events dropped "
                     f"(raise TelemetryConfig.events_capacity)")

    series = rec["series"]
    lines.append("")
    lines.append("series (per report period):")
    for name, label in (("occupancy", "occupancy "),
                        ("rmse_mbps", "rmse_mbps "),
                        ("mean_delay_s", "delay_s   ")):
        vals = series.get(name) or []
        if vals:
            lines.append(f"  {label} {sparkline(vals)}  "
                         f"last={vals[-1]:.3g} max={max(vals):.3g}")

    lines.append("")
    lines.append("stats (over active UE-steps):")
    for name, s in rec["stats"].items():
        lines.append(f"  {name:14s} mean={s['mean']:.4g}  "
                     f"min={s['min']:.4g}  max={s['max']:.4g}")

    admits = [e for e in rec["events"] if e["kind"] == "admit"]
    lats = sorted(e["value"] for e in admits)
    if lats:
        lines.append("")
        lines.append(f"admission latency (periods, {len(lats)} admits): "
                     f"p50={percentile(lats, 50):.1f}  "
                     f"p99={percentile(lats, 99):.1f}  "
                     f"max={lats[-1]:.1f}")

    lines.append("")
    lines.append("event timeline (aggregate admits/departs per period):")
    by_period: dict[int, list] = {}
    for e in rec["events"]:
        by_period.setdefault(e["period"], []).append(e)
    for t in sorted(by_period):
        parts = []
        evs = by_period[t]
        n_admit = sum(1 for e in evs if e["kind"] == "admit")
        n_depart = sum(e["arg"] for e in evs if e["kind"] == "depart")
        if n_admit:
            parts.append(f"+{n_admit} admit")
        if n_depart:
            parts.append(f"-{n_depart} depart")
        for e in evs:
            if e["kind"] in ("admit", "depart"):
                continue
            detail = {"drift_trigger": f"rmse={e['value']:.1f}",
                      "drift_recover": f"rmse={e['value']:.1f}",
                      "burst_start": f"steps={e['arg']}",
                      "burst_end": f"loss={e['value']:.3g}",
                      "handover": f"ues={e['arg']}",
                      }.get(e["kind"], f"arg={e['arg']}")
            parts.append(f"{e['kind']}({detail})")
        lines.append(f"  t={t:4d}  " + "  ".join(parts))

    lines.append("")
    lines.append("histograms:")
    for name, h in rec["hists"].items():
        counts = h["counts"]
        total = sum(counts)
        lines.append(f"  {name} (n={total}):")
        edges = h.get("edges")
        for i, c in enumerate(counts):
            if not c:
                continue
            if name == "split":  # bucket 0 is NO_SPLIT, bucket i split i-1
                label = "NO_SPLIT" if i == 0 else f"split {i - 1:3d}"
            elif edges is not None:
                label = f"[{edges[i]:.3g}, {edges[i + 1]:.3g})"
            else:
                label = f"bin {i}"
            lines.append(f"    {label:>18s} {hbar(c, total)} {c}")
    return "\n".join(lines)


def main(argv: list[str]) -> int:
    path = pathlib.Path(argv[1]) if len(argv) > 1 else DEFAULT
    print(render(load_record(path)))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
