#!/usr/bin/env python
"""Fail on broken intra-repo markdown links (the `make docs-check` gate).

Scans README.md, docs/**/*.md and every in-tree README for
``[text](target)`` links and checks that each relative target resolves to
a real file or directory. External links (http/https/mailto) and pure
in-page anchors (#...) are skipped; a ``path#anchor`` target is checked
for the path only (anchor validity is the renderer's problem, file
existence is ours).

Usage: python tools/docs_check.py [repo_root]
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — target may not contain spaces or parens in our docs
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:")


def doc_files(root: pathlib.Path) -> list[pathlib.Path]:
    files = {root / "README.md"}
    files.update((root / "docs").rglob("*.md"))
    for sub in ("src", "benchmarks", "examples", "tests"):
        files.update((root / sub).rglob("README.md"))
    return sorted(f for f in files if f.is_file())


def check_file(path: pathlib.Path, root: pathlib.Path) -> list[str]:
    errors = []
    text = path.read_text()
    # strip fenced code blocks: ``](...)`` inside them is example syntax
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in LINK.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        base = root if rel.startswith("/") else path.parent
        resolved = (base / rel.lstrip("/")).resolve()
        if not resolved.exists():
            errors.append(f"{path.relative_to(root)}: broken link -> "
                          f"{target}")
    return errors


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else
                        pathlib.Path(__file__).resolve().parents[1])
    files = doc_files(root)
    errors = [e for f in files for e in check_file(f, root)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"docs-check: {len(files)} files, {len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
