"""Train the AI throughput estimator (Fig. 3 / Table I) on simulated 5G
channels and evaluate R^2 / RMSE per scenario.

Run: PYTHONPATH=src python examples/train_estimator.py [--full-iq]
(--full-iq uses the paper's full 3276-row spectrograms; default is 1/3
height for CPU speed — the architecture is identical.)
"""
import argparse

import numpy as np

from repro.channel import scenarios as sc
from repro.estimator.model import EstimatorConfig
from repro.estimator.train import predict, r2_rmse, train_estimator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-iq", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    n_sc = 3276 if args.full_iq else 1092
    e = EstimatorConfig(n_sc=n_sc)  # lstm_hidden=124, window=30 (paper)
    rng = np.random.default_rng(1)
    print("generating channel dataset...")
    tr = sc.gen_dataset(100, rng, episode_len=12, n_sc=n_sc)
    te = sc.gen_dataset(40, rng, episode_len=8, n_sc=n_sc)
    print(f"train={len(tr['tp'])} test={len(te['tp'])} samples, "
          f"iq={tr['iq'].shape[1:]}")
    params, hist, (r2, rmse) = train_estimator(
        e, tr, steps=args.steps, batch=24, eval_data=te, log_every=50)
    for s, l in hist:
        print(f"  step {s:4d} mse {l:9.1f}")
    print(f"TEST: R2={r2:.4f} RMSE={rmse:.3f} Mbps "
          f"(paper: R2=0.9636 RMSE=2.48)")
    pred = predict(e, params, te)
    for i, scen in enumerate(sc.SCENARIOS):
        m = te["scenario"] == i
        if m.sum() > 2:
            r2s, rmses = r2_rmse(pred[m], te["tp"][m])
            print(f"  {scen:8s}: R2={r2s:.3f} RMSE={rmses:.2f} "
                  f"(n={int(m.sum())})")


if __name__ == "__main__":
    main()
