"""Quickstart: the paper's pipeline in one page.

1. Profile VGG16's 43 split points (FLOPs, bytes, privacy).
2. Build the PSO lookup table (Algorithm 1).
3. Drive the adaptive controller through a throughput collapse and watch
   the split move; run the actual split inference at both operating points.

Run: PYTHONPATH=src python examples/quickstart.py
     (--smoke: CI mode — one operating point, same end-to-end path)
"""
import argparse

import jax
import numpy as np

ap = argparse.ArgumentParser(description="paper pipeline quickstart")
ap.add_argument("--smoke", action="store_true",
                help="CI mode: run a single operating point")
ARGS = ap.parse_args()

from repro.core import boundary
from repro.core.controller import AdaptiveSplitController
from repro.core.energy import EDGE_A40X2, UE_VM_2CORE
from repro.core.objective import Constraints, Weights
from repro.core.pso import pso_vectorized
from repro.core.splitting import vgg_head, vgg_tail
from repro.models.vgg import FULL, REDUCED, init_vgg, vgg_split_profile

# 1. profile -------------------------------------------------------------
profile = vgg_split_profile(FULL)
print(f"profile: {profile.n_splits} split points, "
      f"{profile.total_flops/1e9:.1f} GFLOPs total")

# 2. PSO lookup table (Algorithm 1) --------------------------------------
table = pso_vectorized(
    profile, UE_VM_2CORE, EDGE_A40X2,
    Weights(w_delay=1.0, w_privacy=0.15, w_energy=0.1),
    Constraints(rho_max=0.92, tau_max_s=6.0, e_max_j=40.0),
    tp_max_mbps=130)
print("lookup table (TP Mbps -> split):",
      {tp: int(table.table[tp]) + 1 for tp in (5, 10, 20, 40, 80, 130)})

# 3. adaptive control through a throughput collapse ----------------------
ctl = AdaptiveSplitController(table)
for tp in [120, 118, 95, 60, 22, 9, 8, 7, 9, 8]:
    l = ctl.update(tp)
    print(f"  estimator reports {tp:4d} Mbps -> run layers 1..{l+1} on UE")

# actual split inference on the reduced (CPU-sized) VGG ------------------
params = init_vgg(REDUCED, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1),
                      (2, REDUCED.image_size, REDUCED.image_size, 3))
for tp in ((130,) if ARGS.smoke else (130, 8)):
    l = table.query(tp)
    act = vgg_head(REDUCED, params, x, l)  # runs on the UE
    act = boundary.roundtrip(act, boundary.INT8)  # 4x smaller uplink
    out = vgg_tail(REDUCED, params, act, l)  # runs on the edge
    print(f"TP={tp:3d} Mbps: split at {l+1}, boundary "
          f"{np.prod(act.shape)} els, probs sum={float(out.sum()):.3f}")
print("done.")
