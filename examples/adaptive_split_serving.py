"""End-to-end adaptive split serving of an LM over a degrading 5G channel.

The full loop of Fig. 1, CPU-sized: the channel simulator produces KPM/IQ
reports; the AI throughput estimator (trained on the fly here for a few
seconds) feeds the AF controller; the PSO table moves the transformer split
point; head/tail halves actually execute with an int8 boundary codec.

Run: PYTHONPATH=src python examples/adaptive_split_serving.py
"""
import jax
import numpy as np

from repro.channel import scenarios as sc
from repro.channel import throughput as tpm
from repro.channel.iq import spectrogram
from repro.channel.kpm import kpm_window, normalize_kpms
from repro.configs import get_config
from repro.core import boundary
from repro.core.controller import AdaptiveSplitController, ControllerConfig
from repro.core.energy import EDGE_TPU_PARTITION, UE_TPU_PARTITION
from repro.core.objective import Constraints, Weights
from repro.core.profiles import lm_split_profile
from repro.core.pso import pso_vectorized
from repro.core.splitting import lm_head, lm_split_points, lm_tail
from repro.estimator.model import EstimatorConfig
from repro.estimator.train import predict, train_estimator
from repro.models import init_params

SEQ, BATCH, N_SC, LOAD = 32, 2, 144, 0.12

# --- model + split profile ----------------------------------------------
cfg = get_config("granite-8b").reduced(n_layers=6)
params = init_params(cfg, jax.random.PRNGKey(0))
prof = lm_split_profile(cfg, SEQ, BATCH)
prof.data_bytes[:] = boundary.transmit_bytes((BATCH, SEQ, cfg.d_model),
                                             boundary.INT8)
table = pso_vectorized(prof, UE_TPU_PARTITION, EDGE_TPU_PARTITION,
                       Weights(1.0, 0.3, 0.2), Constraints(rho_max=0.9), 130)
print(f"arch={cfg.name}: split points {lm_split_points(cfg)}, "
      f"boundary={int(prof.data_bytes[0])}B int8")

# --- throughput estimator (quick training run) ---------------------------
ecfg = EstimatorConfig(n_sc=N_SC, lstm_hidden=32, hidden=32)
rng = np.random.default_rng(0)
data = sc.gen_dataset(60, rng, episode_len=10, n_sc=N_SC)
eparams, hist, _ = train_estimator(ecfg, data, steps=250, batch=16)
print(f"estimator trained: loss {hist[0][1]:.1f} -> {hist[-1][1]:.1f}")

# --- serve through a jamming ramp ----------------------------------------
ctl = AdaptiveSplitController(table, ControllerConfig(hysteresis_steps=2))
tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0, cfg.vocab)
trace = np.concatenate([np.full(40, -60.0), np.linspace(-25, 9, 25)])
kpms = normalize_kpms(kpm_window(trace, LOAD, rng, "jamming"))
for t in range(sc.WINDOW, len(trace), 5):
    iq = spectrogram(float(trace[t]), "jamming", LOAD, rng, n_sc=N_SC)
    est_tp = float(np.clip(predict(ecfg, eparams, {
        "kpms": kpms[None, t - sc.WINDOW:t], "iq": iq[None],
        "alloc": np.array([LOAD], np.float32),
        "tp": np.zeros(1, np.float32)})[0], 1, 130))
    k = ctl.update(est_tp)
    true_tp = float(tpm.max_throughput_mbps(np.array(trace[t])))
    act = lm_head(cfg, params, {"tokens": tokens}, max(k, 1))
    act = boundary.roundtrip(act, boundary.INT8)
    logits = lm_tail(cfg, params, act, {"tokens": tokens}, max(k, 1))
    print(f"t={t:3d} int={trace[t]:6.1f}dBm true={true_tp:5.1f} "
          f"est={est_tp:5.1f}Mbps -> head blocks=1..{max(k,1)} "
          f"logits[0,0,:2]={np.asarray(logits)[0, 0, :2].round(2)}")
print(f"controller switches: {ctl.switches}")
