"""End-to-end training driver: pretrain an LM with the full runtime stack
(synthetic data pipeline, AdamW + cosine, grad accumulation, async
checkpointing, straggler watchdog, resume).

Presets:
  smoke : ~1M params,   60 steps  (seconds — CI default)
  10m   : ~14M params,  200 steps (minutes on CPU)
  100m  : ~105M params, 300 steps (the deliverable config; hours on 1 CPU
          core, minutes on real accelerators)

Run: PYTHONPATH=src python examples/train_lm.py --preset smoke
     PYTHONPATH=src python examples/train_lm.py --preset 100m --resume
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.runtime.trainer import Trainer, TrainerConfig

PRESETS = {
    "smoke": dict(d_model=128, n_layers=4, n_heads=4, kv_heads=4, head_dim=32,
                  d_ff=512, vocab=2048, seq=64, batch=8, steps=60),
    "10m": dict(d_model=256, n_layers=8, n_heads=8, kv_heads=4, head_dim=32,
                d_ff=1024, vocab=8192, seq=128, batch=8, steps=200),
    "100m": dict(d_model=640, n_layers=10, n_heads=10, kv_heads=5,
                 head_dim=64, d_ff=2560, vocab=32768, seq=256, batch=8,
                 steps=300),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    p = PRESETS[args.preset]
    base = get_config("granite-8b")  # llama-family block structure
    cfg = dataclasses.replace(
        base, name=f"lm-{args.preset}", d_model=p["d_model"],
        n_layers=p["n_layers"], n_heads=p["n_heads"], kv_heads=p["kv_heads"],
        head_dim=p["head_dim"], d_ff=p["d_ff"], vocab=p["vocab"])
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    tc = TrainerConfig(
        seq=p["seq"], global_batch=p["batch"],
        steps=args.steps or p["steps"], ckpt_every=25,
        ckpt_dir=args.ckpt_dir or f"/tmp/repro_{args.preset}",
        lr=1e-3, warmup=20, remat="none")
    trainer = Trainer(cfg, tc, on_straggler=lambda s, a, dt: print(
        f"  [watchdog] step {s}: {a.name} ({dt:.2f}s)"))
    _, hist = trainer.run(resume=args.resume)
    n = max(1, len(hist) // 8)
    for s, l in hist[::n]:
        print(f"step {int(s):4d} loss {l:.4f}")
    drop = hist[0, 1] - hist[-1, 1]
    print(f"final loss {hist[-1,1]:.4f} (drop {drop:.3f}) — "
          f"checkpoints in {tc.ckpt_dir}")
    assert drop > 0, "loss did not improve"


if __name__ == "__main__":
    main()
