"""Table II: throughput-estimator comparison.

Paper:  A XGBoost, 7 KPMs          R2 0.3160  RMSE 10.77
        B XGBoost, 15 KPMs         R2 0.7845  RMSE  6.05
        C proposed (KPM ts + IQ)   R2 0.9636  RMSE  2.48
Here (no xgboost offline): A/B become ridge + MLP on the same feature sets;
low-load interference regime, where the paper's gap comes from. The
reproduction target is the ordering and the IQ-fusion gap.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FAST, record
from repro.channel import scenarios as sc
from repro.estimator.baselines import (mlp_fit_predict, ridge_fit,
                                       ridge_predict, summary_features)
from repro.estimator.model import EstimatorConfig
from repro.estimator.train import r2_rmse, train_estimator

N_SC = 364 if FAST else 1092  # spectrogram rows (full 3276 in unit tests)


def run(state: dict) -> None:
    t0 = time.time()
    rng = np.random.default_rng(42)
    n_tr, n_te, steps = (40, 15, 120) if FAST else (150, 60, 400)
    tr = sc.gen_dataset(n_tr, rng, episode_len=12, low_load_only=True,
                        n_sc=N_SC)
    te = sc.gen_dataset(n_te, rng, episode_len=8, low_load_only=True,
                        n_sc=N_SC)
    rows = {}
    for name, fs in (("A_ridge_7kpm", "kpm7"), ("B_ridge_15kpm", "kpm15")):
        w = ridge_fit(summary_features(tr["kpms"], fs), tr["tp"])
        rows[name] = r2_rmse(
            ridge_predict(w, summary_features(te["kpms"], fs)), te["tp"])
    for name, fs in (("A_mlp_7kpm", "kpm7"), ("B_mlp_15kpm", "kpm15")):
        pred = mlp_fit_predict(summary_features(tr["kpms"], fs), tr["tp"],
                               summary_features(te["kpms"], fs))
        rows[name] = r2_rmse(pred, te["tp"])
    e = EstimatorConfig(n_sc=N_SC, lstm_hidden=64, hidden=64)
    params, _, (r2c, rmsec) = train_estimator(
        e, tr, steps=steps, batch=24, eval_data=te, log_every=200)
    rows["C_proposed_kpm_ts_plus_iq"] = (r2c, rmsec)
    state["estimator"] = (e, params)
    state["table2"] = rows
    paper = {"A": (0.3160, 10.7748), "B": (0.7845, 6.0478),
             "C": (0.9636, 2.4839)}
    for name, (r2, rmse) in rows.items():
        ref = paper.get(name[0], ("", ""))
        record(f"table2/{name}", t0,
               f"r2={r2:.4f};rmse={rmse:.3f};paper_r2={ref[0]};"
               f"paper_rmse={ref[1]}")
    ok = (rows["C_proposed_kpm_ts_plus_iq"][0] >
          max(rows["B_ridge_15kpm"][0], rows["B_mlp_15kpm"][0]) >=
          min(rows["A_ridge_7kpm"][0], rows["A_mlp_7kpm"][0]))
    record("table2/ordering_A<B<C", t0, f"reproduced={ok}")
