"""Fig. 2a/2b: KPM-vs-throughput correlation across interference zones.

2a (high load): TPC ramps in the Power-Control zone, MCS steps down in the
MCS-Control zone, BLER saturates in OOC while HARQ RV2/3 counters appear.
2b (low load): the same KPMs barely move although max achievable throughput
collapses — the motivating observation for the IQ branch.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import record
from repro.channel import kpm as kpmmod
from repro.channel import throughput as tpm


def _corr(a, b):
    a, b = np.asarray(a, float), np.asarray(b, float)
    if a.std() < 1e-9 or b.std() < 1e-9:
        return 0.0
    return float(np.corrcoef(a, b)[0, 1])


def run(state: dict) -> None:
    t0 = time.time()
    grid = np.linspace(-40, 13, 60)
    tp = tpm.max_throughput_mbps(grid)
    rng = np.random.default_rng(5)
    for load, tag in ((0.95, "fig2a_high_load"), (0.10, "fig2b_low_load")):
        rows = kpmmod.kpm_window(grid, load, rng)
        i = {k: kpmmod.KPMS_15.index(k) for k in
             ("tpc", "ul_mcs", "ul_bler", "pusch_sinr")}
        corr = {k: _corr(rows[:, v], tp) for k, v in i.items()}
        record(f"fig2/{tag}", t0,
               f"corr_mcs_tp={corr['ul_mcs']:.2f};"
               f"corr_bler_tp={corr['ul_bler']:.2f};"
               f"corr_tpc_tp={corr['tpc']:.2f};"
               f"corr_sinr_tp={corr['pusch_sinr']:.2f}")
    # the reproduction claim: KPMs are informative at high load, blind at low
    hi = kpmmod.kpm_window(grid, 0.95, rng)
    lo = kpmmod.kpm_window(grid, 0.10, rng)
    im = kpmmod.KPMS_15.index("ul_mcs")
    record("fig2/low_load_blindness", t0,
           f"mcs_range_high_load={np.ptp(hi[:, im]):.0f};"
           f"mcs_range_low_load={np.ptp(lo[:, im]):.0f};"
           f"tp_range={np.ptp(tp):.0f}Mbps")
