"""Roofline table from the dry-run artifacts (EXPERIMENTS.md source).

Reads benchmarks/results/dryrun/pod1/*.json (+ pod2 compile proof) and
emits one CSV row per (arch x shape) with the three terms, bottleneck,
usefulness ratio and HBM fit.
"""
from __future__ import annotations

import json
import pathlib
import time

from benchmarks.common import record

DIR = pathlib.Path(__file__).parent / "results" / "dryrun"


def load_cells(pod: str = "pod1") -> list[dict]:
    out = []
    for f in sorted((DIR / pod).glob("*.json")):
        if "__" in f.stem and f.stem.count("__") == 1:
            out.append(json.loads(f.read_text()))
    return out


def run(state: dict) -> None:
    t0 = time.time()
    pod2 = {(d["arch"], d["shape"]): d for d in load_cells("pod2")}
    n_ok2 = sum(1 for d in pod2.values() if d["status"] == "ok")
    cells = load_cells("pod1")
    state["roofline_cells"] = cells
    for d in cells:
        name = f"roofline/{d['arch']}/{d['shape']}"
        if d["status"] != "ok":
            record(name, t0, f"status={d['status']}")
            continue
        r = d["roofline"]
        p2 = pod2.get((d["arch"], d["shape"]), {}).get("status", "missing")
        record(name, t0,
               f"t_compute={r['t_compute_s']:.4f};t_memory="
               f"{r['t_memory_s']:.4f};t_collective={r['t_collective_s']:.4f};"
               f"bottleneck={r['bottleneck']};useful="
               f"{r['useful_flops_ratio']:.3f};mfu_bound={r['mfu_bound']:.3f};"
               f"fits16GB={r.get('fits_16gb_hbm')};ga={d.get('grad_accum')};"
               f"pod2={p2}")
    ok1 = sum(1 for d in cells if d["status"] == "ok")
    record("roofline/summary", t0,
           f"pod1_ok={ok1};pod2_ok={n_ok2};"
           f"skips={sum(1 for d in cells if d['status'].startswith('skip'))}")
