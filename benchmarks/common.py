"""Shared benchmark plumbing: timing + CSV rows + fast-mode switch."""
from __future__ import annotations

import os
import time

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
RESULTS: list[tuple[str, float, str]] = []


def record(name: str, t0: float, derived: str):
    us = (time.time() - t0) * 1e6
    RESULTS.append((name, us, derived))
    print(f"{name},{us:.0f},{derived}", flush=True)


def emit_header():
    print("name,us_per_call,derived", flush=True)
