"""Shared benchmark plumbing: timing + CSV rows + fast-mode switch +
machine-config-stamped JSON output."""
from __future__ import annotations

import json
import os
import platform
import time

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
RESULTS: list[tuple[str, float, str]] = []


def machine_config() -> dict:
    """The machine/devices side of every benchmark record: BENCH_*
    trajectories are only comparable across runs when the backing
    platform, device count and jax build ride along in the JSON."""
    cfg: dict = {"python": platform.python_version(),
                 "machine": platform.machine(),
                 "cpu_count": os.cpu_count(), "fast": FAST,
                 "xla_flags": os.environ.get("XLA_FLAGS", "")}
    try:
        import jax
        cfg.update(jax=jax.__version__, backend=jax.default_backend(),
                   device_count=jax.device_count(),
                   device_kind=jax.devices()[0].device_kind)
    except Exception:  # pragma: no cover - jax import is all-or-nothing
        pass
    return cfg


def write_json(path: str, extra: dict | None = None) -> None:
    """Dump every ``record()`` row plus :func:`machine_config` (and any
    sweep-specific ``extra``, e.g. the serving-mesh shape) to ``path``."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    payload = {"config": machine_config(), **(extra or {}),
               "records": [{"name": n, "us_per_call": us, "derived": d}
                           for n, us, d in RESULTS]}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def record(name: str, t0: float, derived: str):
    us = (time.time() - t0) * 1e6
    RESULTS.append((name, us, derived))
    print(f"{name},{us:.0f},{derived}", flush=True)


def emit_header():
    print("name,us_per_call,derived", flush=True)
