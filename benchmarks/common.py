"""Shared benchmark plumbing: timing + CSV rows + fast-mode switch +
machine-config-stamped JSON output."""
from __future__ import annotations

import datetime
import json
import os
import platform
import subprocess
import time

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
RESULTS: list[tuple[str, float, str]] = []


class stopwatch:
    """``with stopwatch() as sw: body`` — ``sw.seconds`` is the wall time
    of the body (``perf_counter``; read it after the block exits)."""

    seconds = 0.0

    def __enter__(self) -> "stopwatch":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.seconds = time.perf_counter() - self._t0
        return False


def git_sha() -> str | None:
    """The repo HEAD a committed record was produced at (None outside a
    checkout or without git on PATH)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=10)
    except (OSError, subprocess.SubprocessError):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def machine_config() -> dict:
    """The machine/devices side of every benchmark record: BENCH_*
    trajectories are only comparable across runs when the backing
    platform, device count and jax build ride along in the JSON."""
    cfg: dict = {"python": platform.python_version(),
                 "machine": platform.machine(),
                 "cpu_count": os.cpu_count(), "fast": FAST,
                 "xla_flags": os.environ.get("XLA_FLAGS", "")}
    try:
        import jax
        import jaxlib
        cfg.update(jax=jax.__version__, jaxlib=jaxlib.__version__,
                   backend=jax.default_backend(),
                   device_count=jax.device_count(),
                   device_kind=jax.devices()[0].device_kind)
    except Exception:  # pragma: no cover - jax import is all-or-nothing
        pass
    return cfg


def write_json(path: str, extra: dict | None = None) -> None:
    """Dump every ``record()`` row plus :func:`machine_config` (and any
    sweep-specific ``extra``, e.g. the serving-mesh shape) to ``path``.
    Every committed record is provenance-stamped: repo git SHA, jax +
    jaxlib versions (in the machine config), and an ISO-8601 UTC
    timestamp — a BENCH_* trajectory is only evidence when the reader can
    tell which code produced which number, and when."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    stamp = datetime.datetime.now(datetime.timezone.utc)
    payload = {"config": machine_config(), "git_sha": git_sha(),
               "timestamp": stamp.isoformat(timespec="seconds"),
               **(extra or {}),
               "records": [{"name": n, "us_per_call": us, "derived": d}
                           for n, us, d in RESULTS]}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def record(name: str, t0: float, derived: str):
    us = (time.time() - t0) * 1e6
    RESULTS.append((name, us, derived))
    print(f"{name},{us:.0f},{derived}", flush=True)


def emit_header():
    print("name,us_per_call,derived", flush=True)
