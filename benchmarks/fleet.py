"""Fleet-size sweep on the ``repro.sim`` engine.

Simulates fleets of N UEs (mixed S0-S3 interference, heterogeneous UL
loads, mid-episode scenario handover for a quarter of the fleet) through
the vectorized controller -> PSO -> metrics path, and reports

  * per-fleet delay / energy / privacy aggregates per scenario group,
  * wall-clock engine throughput in UE-steps/sec,
  * the speedup over the legacy per-UE, per-step looped path, and
  * an equivalence check: the single-UE fig6 configuration run through the
    engine matches the sequential implementation to float tolerance.

Run:  PYTHONPATH=src python benchmarks/fleet.py [--fast] [--sizes 1 64 1024]
Also exposed as ``run(state)`` for benchmarks/run.py.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/fleet.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks import fig6_adaptive
from benchmarks.common import FAST, record
from repro.channel.scenarios import SCENARIOS, WINDOW, gen_episode_batch
from repro.sim import simulate_fleet, simulate_fleet_looped

LOOP_REF_UES = 32  # the looped path is timed on a slice this big (its
# per-UE cost is constant, so the UE-steps/sec rate transfers to any N)


def build_fleet_episode(n: int, T: int, rng: np.random.Generator,
                        handover_frac: float = 0.25):
    """Mixed-scenario fleet: scenarios cycle S0-S3 across UEs, loads are
    heterogeneous, and ``handover_frac`` of the fleet hands over to the
    next scenario mid-episode."""
    base = np.asarray(SCENARIOS)[np.arange(n) % len(SCENARIOS)]
    grid = np.repeat(base[:, None], T + WINDOW, axis=1)
    n_h = int(round(n * handover_frac))
    hover = rng.choice(n, n_h, replace=False) if n_h else np.array([], int)
    nxt = np.asarray(SCENARIOS)[(np.arange(n) + 1) % len(SCENARIOS)]
    grid[hover, WINDOW + T // 2:] = nxt[hover, None]
    loads = rng.uniform(0.05, 1.0, n)
    ep = gen_episode_batch(grid, T, rng, load_ratio=loads, include_iq=False)
    return ep, hover


def check_fig6_equivalence(prof, table, cfg, fixed, t0) -> bool:
    """The fig6 configuration (one UE per scenario at its operating point)
    through the engine vs the sequential per-UE loop: split decisions must
    be identical and per-scenario metric means equal to float tolerance."""
    rng = np.random.default_rng(123)
    ep = fig6_adaptive.fig6_episode(rng, 30, 0.12, None)
    vec = simulate_fleet(ep, table, prof, cfg, warm_split=fixed,
                         fixed_split=fixed)
    loop = simulate_fleet_looped(ep, table, prof, cfg, warm_split=fixed,
                                 fixed_split=fixed)
    splits_eq = np.array_equal(vec.splits, loop.splits)
    mv, ml = (r.scenario_means(ep.scenario_idx) for r in (vec, loop))
    mean_err = max(float(np.max(np.abs(mv[s] - ml[s]) / np.abs(ml[s])))
                   for s in mv)
    ok = splits_eq and mean_err < 1e-9
    record("fleet/fig6_equivalence", t0,
           f"splits_identical={splits_eq};scenario_mean_max_relerr="
           f"{mean_err:.2e};ok={ok}")
    return ok


def fleet_cell(n: int, T: int, prof, table, cfg, fixed, rng, t0,
               speedup_at: int | None = None) -> dict:
    ep, hover = build_fleet_episode(n, T, rng)
    simulate_fleet(ep, table, prof, cfg, fixed_split=fixed)  # warm the jit
    t1 = time.perf_counter()
    res = simulate_fleet(ep, table, prof, cfg, fixed_split=fixed)
    dt = time.perf_counter() - t1
    rate = n * T / dt
    means = res.scenario_means(ep.scenario_idx)
    hmask = np.zeros(n, bool)
    hmask[hover] = True
    agg = ";".join(
        f"{s}_delay_ms={m[0]*1e3:.0f};{s}_energy_J={m[2]:.2f};"
        f"{s}_privacy={m[1]:.3f}" for s, m in sorted(means.items()))
    ho = (f";handover_delay_ms={res.delay_s[hmask].mean()*1e3:.0f}"
          if hmask.any() else "")
    out = {"n": n, "rate": rate, "means": means}
    derived = f"ue_steps_per_sec={rate:.0f};{agg}{ho}"
    if speedup_at is not None and n >= speedup_at:
        m = min(n, LOOP_REF_UES)
        sub, _ = build_fleet_episode(m, T, rng)
        simulate_fleet_looped(sub, table, prof, cfg, fixed_split=fixed)
        t2 = time.perf_counter()
        simulate_fleet_looped(sub, table, prof, cfg, fixed_split=fixed)
        loop_rate = m * T / (time.perf_counter() - t2)
        out["speedup"] = rate / loop_rate
        derived += (f";looped_ue_steps_per_sec={loop_rate:.0f};"
                    f"speedup_x={rate / loop_rate:.0f};"
                    f"speedup>=50x={rate / loop_rate >= 50.0}")
    record(f"fleet/n{n}", t0, derived)
    return out


def run(state: dict, sizes=None, T: int | None = None) -> bool:
    t0 = time.time()
    prof = state.get("vgg_profile")
    if prof is None:
        from repro.models.vgg import FULL, vgg_split_profile
        prof = state["vgg_profile"] = vgg_split_profile(FULL)
    # the fig6 configuration, shared so the equivalence check below always
    # exercises exactly what benchmarks/fig6_adaptive.py runs
    table, cfg, fixed = fig6_adaptive.fig6_table(prof)
    sizes = sizes or ([1, 64, 1024] if FAST else [1, 64, 1024, 4096])
    T = T or (30 if FAST else 100)
    ok_eq = check_fig6_equivalence(prof, table, cfg, fixed, t0)
    rng = np.random.default_rng(7)
    cells = [fleet_cell(n, T, prof, table, cfg, fixed, rng, t0,
                        speedup_at=max(sizes)) for n in sizes]
    state["fleet"] = cells
    speedups = [c["speedup"] for c in cells if "speedup" in c]
    ok_speed = bool(speedups) and max(speedups) >= 50.0
    record("fleet/claims", t0,
           f"fig6_equivalence={ok_eq};max_fleet={max(sizes)};"
           f"speedup>=50x={ok_speed}")
    return ok_eq and ok_speed


def main() -> int:
    ap = argparse.ArgumentParser(description="fleet-size sweep")
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: short episodes, sizes 1/64/1024")
    ap.add_argument("--sizes", type=int, nargs="+", default=None)
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    if args.fast:
        import benchmarks.common as common
        common.FAST = True
        global FAST
        FAST = True
    sizes = args.sizes or ([1, 64, 1024] if (FAST or args.fast)
                           else [1, 64, 1024, 4096])
    T = args.steps or (30 if (FAST or args.fast) else 100)
    ok = run({}, sizes=sizes, T=T)
    print(f"# fleet sweep {'OK' if ok else 'FAILED'}", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
