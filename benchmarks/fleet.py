"""Fleet-size sweep on the ``repro.sim`` engine.

Simulates fleets of N UEs (mixed S0-S3 interference, heterogeneous UL
loads, mid-episode scenario handover for a quarter of the fleet) through
the vectorized controller -> PSO -> metrics path, and reports

  * per-fleet delay / energy / privacy aggregates per scenario group,
  * wall-clock engine throughput in UE-steps/sec,
  * the speedup over the legacy per-UE, per-step looped path, and
  * an equivalence check: the single-UE fig6 configuration run through the
    engine matches the sequential implementation to float tolerance.

With ``--cells C`` the sweep instead runs the multi-cell contended
setting (``repro.sim.cells``): UEs spread over C load-coupled cells, a
quarter of the fleet handing over to the neighbour cell mid-episode, and
each cell's gNB arbitrating PRBs per report period under every requested
``--policy`` (rr / pf / maxsinr). Reports per-policy Jain fairness of the
served throughput next to the fig6-style delay / energy / privacy
aggregates, plus a 1-cell no-coupling equivalence pin against the
uncontended engine (the scheduler hook is a no-op by default).

With ``--mesh DxM`` (or ``DxExM`` for the expert-parallel variant) it
runs the estimator-serving sweep instead: per-report-period fleet
inference (``estimate_fleet``) mesh-sharded over the host mesh via
``repro.sim.serving`` vs the unsharded path, reporting UE-steps/s for
both, the real-time UE capacity per chip, an allclose pin between the
two, and the sched=None bit-identical regression. ``--json PATH`` dumps
every record plus the machine + mesh config for cross-machine BENCH_*
comparison.

With ``--churn`` it runs the slot-pool sweep (``repro.sim.pool``): a
fixed-capacity pool of 1024–4096 UE slots serving a *continuously
churning* population — Poisson arrivals with a diurnal tide, geometric
dwell times, admission through fixed lanes — at 10–50% churn per report
period. Reports sustained UE-steps/s (occupied-slot periods over wall
clock), p99 admission latency in periods, mean occupancy, and a
no-retrace assertion: after warmup the jitted per-period program must
not recompile as the population churns (the whole point of the fixed
shapes). Also pins the full-pool configuration (every session arrives at
t=0 and never departs) bit-identical on splits to the batch engine.

With ``--profile`` it profiles the per-period fleet step: a per-stage
wall-time breakdown (featurize / estimator forward / PSO query /
scheduler scan / load coupling, plus the recurrent ``ssm_step`` — the
SSM serving path has no featurize stage at all, so its evidence is the
O(1)-in-history flatness probe rather than a fused/unfused pair), each
windowed stage unfused vs fused through the ``repro.kernels`` Pallas
paths, the end-to-end engine before/after fusing (with an allclose
pin), the int8 estimator forward next to fp32, and the slot-pool path
at scale against the committed
``benchmarks/results/churn_smoke.json`` baseline. Every stage lands in
the ``--json`` record as best/median/spread milliseconds, so fusion
targets and speedups are evidence even on noisy hosts.

With ``--online`` it runs the drift sweep (``repro.sim.online``): an
estimator trained offline on a quiet scenario distribution serves a
fleet whose every UE jumps to an unseen interference regime mid-episode
(a scenario-*distribution* shift, not the usual quarter-fleet handover),
frozen vs online-adapted. Reports pre/post-drift estimator RMSE for
both, the fig6-style delay/energy/privacy means, the UE-steps/s overhead
of the closed loop, and the online=None bit-identity regression.
``--online --estimator ssm`` runs the head-to-head instead: the
recurrent SSM estimator (``repro.estimator.ssm``) next to the windowed
LSTM on the SAME drift episode — pre/post-drift RMSE for both families
(frozen and adapted), UE-steps/s, per-UE serving-state bytes (constant
SSD state vs window + IQ inputs), the K-period forecast variant sharing
the trained weights, and the persistence floor the forecasts must beat.

With ``--telemetry`` it runs the observability smoke
(``repro.sim.telemetry``): the estimator-driven churn run with the
in-scan metric plane on vs off — splits and estimates must stay
bit-identical, wall-clock overhead must stay within 5%, and the compiled
pool program must not retrace — plus a small churn + online-adaptation
cell whose decoded event timeline (admissions with queue latency,
departures, drift triggers, adaptation bursts) lands in the ``--json``
record so ``tools/fleetmon.py`` can render run health from the committed
artifact.

Run:  PYTHONPATH=src python benchmarks/fleet.py [--fast] [--sizes 1 64 1024]
      PYTHONPATH=src python benchmarks/fleet.py --cells 4 --policy pf
      PYTHONPATH=src python benchmarks/fleet.py --mesh 4x2 --fast
      PYTHONPATH=src python benchmarks/fleet.py --online [--json out.json]
      PYTHONPATH=src python benchmarks/fleet.py --online --estimator ssm
      PYTHONPATH=src python benchmarks/fleet.py --churn [--sizes 1024 4096]
      PYTHONPATH=src python benchmarks/fleet.py --telemetry --sizes 1024
      PYTHONPATH=src python benchmarks/fleet.py --profile [--json out.json]
Also exposed as ``run(state)`` for benchmarks/run.py.
"""
from __future__ import annotations

import argparse
import os
import pathlib
import sys
import time

# the mesh sweep wants several host devices; must be decided before the
# repro imports below transitively import jax (both --mesh SPEC and
# --mesh=SPEC spellings)
if any(a == "--mesh" or a.startswith("--mesh=") for a in sys.argv) and (
        "xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/fleet.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks import fig6_adaptive
from benchmarks.common import FAST, record, stopwatch, write_json
from repro.channel.scenarios import (SCENARIOS, WINDOW, ChurnConfig,
                                     ChurnSchedule, gen_episode_batch,
                                     make_churn_schedule)
from repro.sim import (DriftConfig, OnlineConfig, SchedulerConfig,
                       TelemetryConfig, attach_ring, build_cells_episode,
                       estimate_fleet, handover_grid, make_serving_mesh,
                       ring_coupling, simulate_cells, simulate_fleet,
                       simulate_fleet_looped, timed)
from repro.sim.pool import pool_programs
from repro.sim.sched import POLICIES

LOOP_REF_UES = 32  # the looped path is timed on a slice this big (its
# per-UE cost is constant, so the UE-steps/sec rate transfers to any N)

REPORT_PERIOD_S = 0.1  # the AF's estimator report period: serving a fleet
# in real time means one whole-fleet predict within this budget


def _vgg_profile(state: dict):
    """The lazily-built VGG16 split profile, cached in the shared benchmark
    ``state`` so every sweep (fleet/cells/mesh/online/churn/profile) builds
    it at most once per process."""
    prof = state.get("vgg_profile")
    if prof is None:
        from repro.models.vgg import FULL, vgg_split_profile
        prof = state["vgg_profile"] = vgg_split_profile(FULL)
    return prof


def scenario_grid(n: int, T: int, rng: np.random.Generator,
                  handover_frac: float = 0.25):
    """(N, T + WINDOW) scenario grid: scenarios cycle S0-S3 across UEs and
    ``handover_frac`` of the fleet hands over to the next scenario
    mid-episode. Returns the grid and the handed-over UE indices."""
    base = np.asarray(SCENARIOS)[np.arange(n) % len(SCENARIOS)]
    grid = np.repeat(base[:, None], T + WINDOW, axis=1)
    n_h = int(round(n * handover_frac))
    hover = rng.choice(n, n_h, replace=False) if n_h else np.array([], int)
    nxt = np.asarray(SCENARIOS)[(np.arange(n) + 1) % len(SCENARIOS)]
    grid[hover, WINDOW + T // 2:] = nxt[hover, None]
    return grid, hover


def build_fleet_episode(n: int, T: int, rng: np.random.Generator,
                        handover_frac: float = 0.25):
    """Mixed-scenario fleet: scenarios cycle S0-S3 across UEs, loads are
    heterogeneous, and ``handover_frac`` of the fleet hands over to the
    next scenario mid-episode."""
    grid, hover = scenario_grid(n, T, rng, handover_frac)
    loads = rng.uniform(0.05, 1.0, n)
    ep = gen_episode_batch(grid, T, rng, load_ratio=loads, include_iq=False)
    return ep, hover


def check_fig6_equivalence(prof, table, cfg, fixed, t0) -> bool:
    """The fig6 configuration (one UE per scenario at its operating point)
    through the engine vs the sequential per-UE loop: split decisions must
    be identical and per-scenario metric means equal to float tolerance."""
    rng = np.random.default_rng(123)
    ep = fig6_adaptive.fig6_episode(rng, 30, 0.12, None)
    vec = simulate_fleet(ep, table, prof, cfg, warm_split=fixed,
                         fixed_split=fixed)
    loop = simulate_fleet_looped(ep, table, prof, cfg, warm_split=fixed,
                                 fixed_split=fixed)
    splits_eq = np.array_equal(vec.splits, loop.splits)
    mv, ml = (r.scenario_means(ep.scenario_idx) for r in (vec, loop))
    mean_err = max(float(np.max(np.abs(mv[s] - ml[s]) / np.abs(ml[s])))
                   for s in mv)
    ok = splits_eq and mean_err < 1e-9
    record("fleet/fig6_equivalence", t0,
           f"splits_identical={splits_eq};scenario_mean_max_relerr="
           f"{mean_err:.2e};ok={ok}")
    return ok


def fleet_cell(n: int, T: int, prof, table, cfg, fixed, rng, t0,
               speedup_at: int | None = None) -> dict:
    ep, hover = build_fleet_episode(n, T, rng)
    simulate_fleet(ep, table, prof, cfg, fixed_split=fixed)  # warm the jit
    with stopwatch() as sw:
        res = simulate_fleet(ep, table, prof, cfg, fixed_split=fixed)
    rate = n * T / sw.seconds
    means = res.scenario_means(ep.scenario_idx)
    hmask = np.zeros(n, bool)
    hmask[hover] = True
    agg = ";".join(
        f"{s}_delay_ms={m[0]*1e3:.0f};{s}_energy_J={m[2]:.2f};"
        f"{s}_privacy={m[1]:.3f}" for s, m in sorted(means.items()))
    ho = (f";handover_delay_ms={res.delay_s[hmask].mean()*1e3:.0f}"
          if hmask.any() else "")
    out = {"n": n, "rate": rate, "means": means}
    derived = f"ue_steps_per_sec={rate:.0f};{agg}{ho}"
    if speedup_at is not None and n >= speedup_at:
        m = min(n, LOOP_REF_UES)
        sub, _ = build_fleet_episode(m, T, rng)
        simulate_fleet_looped(sub, table, prof, cfg, fixed_split=fixed)
        with stopwatch() as sw:
            simulate_fleet_looped(sub, table, prof, cfg, fixed_split=fixed)
        loop_rate = m * T / sw.seconds
        out["speedup"] = rate / loop_rate
        derived += (f";looped_ue_steps_per_sec={loop_rate:.0f};"
                    f"speedup_x={rate / loop_rate:.0f};"
                    f"speedup>=50x={rate / loop_rate >= 50.0}")
    record(f"fleet/n{n}", t0, derived)
    return out


def check_cells_equivalence(prof, table, cfg, fixed, t0) -> bool:
    """1 cell + no coupling + no scheduler through the cells layer must be
    the PR-2 engine, bit-for-bit on splits and float-identical on
    metrics: the scheduler hook is a no-op by default."""
    rng = np.random.default_rng(11)
    n, T = 64, 20
    grid, _ = scenario_grid(n, T, rng)
    cgrid = np.zeros((n, T + WINDOW), int)
    ep = build_cells_episode(grid, T, rng, cgrid, None)
    base = simulate_fleet(ep, table, prof, cfg, fixed_split=fixed)
    cell = simulate_cells(ep, cgrid, table, prof, cfg, sched=None,
                          fixed_split=fixed)
    splits_eq = np.array_equal(cell.fleet.splits, base.splits)
    metrics_eq = all(np.array_equal(getattr(cell.fleet, f), getattr(base, f))
                     for f in ("delay_s", "privacy", "energy_j"))
    ok = splits_eq and metrics_eq
    record("cells/noop_equivalence", t0,
           f"splits_identical={splits_eq};metrics_identical={metrics_eq};"
           f"ok={ok}")
    return ok


def cells_cell(n: int, T: int, n_cells: int, policy: str, prof, table, cfg,
               fixed, rng, t0) -> dict:
    """One contended configuration: N UEs over C coupled cells under one
    scheduling policy, with scenario + inter-cell handover."""
    grid, _ = scenario_grid(n, T, rng)
    cgrid = handover_grid(attach_ring(n, n_cells), T + WINDOW, 0.25, rng,
                          n_cells=n_cells)
    ep = build_cells_episode(grid, T, rng, cgrid, ring_coupling(n_cells))
    sched = SchedulerConfig(policy=policy)
    kw = dict(sched=sched, fixed_split=fixed)
    simulate_cells(ep, cgrid, table, prof, cfg, **kw)  # warm the jit
    with stopwatch() as sw:
        res = simulate_cells(ep, cgrid, table, prof, cfg, **kw)
    rate = n * T / sw.seconds
    cons_dev = float(np.abs(res.share_sums() - 1.0).max())
    jain = res.jain()
    out = {"n": n, "cells": n_cells, "policy": policy, "rate": rate,
           "jain": jain, "cons_dev": cons_dev}
    record(f"cells/n{n}_c{n_cells}_{policy}", t0,
           f"ue_steps_per_sec={rate:.0f};jain={jain:.3f};"
           f"served_mbps_mean={res.served_mbps.mean():.2f};"
           f"delay_ms={res.fleet.delay_s.mean()*1e3:.0f};"
           f"energy_J={res.fleet.energy_j.mean():.2f};"
           f"privacy={res.fleet.privacy.mean():.3f};"
           f"prb_conservation_dev={cons_dev:.1e};cell_handover_ues="
           f"{int((res.cell_idx[:, 0] != res.cell_idx[:, -1]).sum())}")
    return out


def run_cells(state: dict, n_cells: int, policies=None, sizes=None,
              T: int | None = None) -> bool:
    """Per-policy multi-cell sweep + the no-op equivalence pin."""
    t0 = time.time()
    prof = _vgg_profile(state)
    table, cfg, fixed = fig6_adaptive.fig6_table(prof)
    policies = policies or list(POLICIES)
    sizes = sizes or [64, 1024]
    T = T or (30 if FAST else 100)
    ok_eq = check_cells_equivalence(prof, table, cfg, fixed, t0)
    rng = np.random.default_rng(7)
    cells = [cells_cell(n, T, n_cells, p, prof, table, cfg, fixed, rng, t0)
             for n in sizes for p in policies]
    state["cells"] = cells
    ok_cons = all(c["cons_dev"] < 1e-3 for c in cells)
    # max C/I starves; rr must be measurably fairer at the SAME fleet size
    # (Jain is strongly n-dependent, so never compare across sizes)
    jain = {(c["n"], c["policy"]): c["jain"] for c in cells}
    ok_fair = all(jain[(n, "maxsinr")] < jain[(n, "rr")] for n in sizes
                  if ("maxsinr" in policies and "rr" in policies))
    record("cells/claims", t0,
           f"noop_equivalence={ok_eq};prb_conservation={ok_cons};"
           f"maxsinr_less_fair_than_rr={ok_fair};"
           f"max_fleet={max(sizes)};n_cells={n_cells};"
           f"policies={'/'.join(policies)}")
    return ok_eq and ok_cons and ok_fair


def mesh_estimator():
    """Reduced estimator for the serving sweep (random weights: the sweep
    measures serving capacity, not accuracy — same layer shapes/dataflow
    as the paper's, spectrogram height cut so CPU hosts finish)."""
    import jax
    from repro.estimator.model import EstimatorConfig, init_estimator
    e = EstimatorConfig(n_sc=64 if FAST else 256, lstm_hidden=16, hidden=16)
    return e, init_estimator(e, jax.random.PRNGKey(0))


def int8_table2_eval(est, rng, t0) -> dict:
    """int8 vs fp32 estimator accuracy on a table2-style eval set (the
    low-load regime ``benchmarks/table2_estimator.py`` evaluates in): the
    RMSE the int8 weights give up, in Mbps. Served through the jnp oracle
    form (bit-identical to the Pallas int8 kernels — integer accumulation
    is exact — and far cheaper than interpret-mode kernels on CPU)."""
    from repro.channel.scenarios import gen_dataset
    from repro.estimator.serve import predict_int8, quantize_estimator
    from repro.estimator.train import predict, r2_rmse
    e, params = est
    te = gen_dataset(8 if FAST else 24, rng, episode_len=6,
                     low_load_only=True, n_sc=e.n_sc)
    p32 = predict(e, params, te)
    qparams = quantize_estimator(params, use_kernel=False)
    p8 = predict_int8(e, qparams, te, use_kernel=False)
    rmse32 = r2_rmse(p32, te["tp"])[1]
    rmse8 = r2_rmse(p8, te["tp"])[1]
    delta = abs(rmse8 - rmse32)
    pred_dev = float(np.sqrt(np.mean((np.asarray(p8, float)
                                      - np.asarray(p32, float)) ** 2)))
    # weight footprint: int8 matrices + f32 rowwise scales vs f32 weights
    import jax
    f32_bytes = sum(x.size * x.dtype.itemsize
                    for x in jax.tree_util.tree_leaves(params))
    q_bytes = sum(x.size * x.dtype.itemsize
                  for x in jax.tree_util.tree_leaves(qparams))
    out = {"rmse_fp32": rmse32, "rmse_int8": rmse8,
           "rmse_delta_mbps": delta, "pred_rmse_vs_fp32_mbps": pred_dev,
           "weight_bytes_fp32": f32_bytes, "weight_bytes_int8": q_bytes,
           "ok": delta < 1.0 and pred_dev < 1.0}
    record("mesh/int8_table2", t0,
           f"rmse_fp32={rmse32:.3f};rmse_int8={rmse8:.3f};"
           f"rmse_delta_mbps={delta:.3f};"
           f"pred_rmse_vs_fp32_mbps={pred_dev:.3f};"
           f"weight_bytes_fp32={f32_bytes};weight_bytes_int8={q_bytes};"
           f"ok={out['ok']}")
    return out


def mesh_sweep_cell(n: int, T: int, est, serving, rng, t0) -> dict:
    """One fleet size: unsharded vs mesh-sharded per-period inference."""
    grid, _ = scenario_grid(n, T, rng)
    ep = gen_episode_batch(grid, T, rng, include_iq=True, n_sc=est[0].n_sc)
    base = estimate_fleet(ep, est)  # warm the single-device jit
    with stopwatch() as sw_base:
        base = estimate_fleet(ep, est)
    dt_base = sw_base.seconds
    shd = estimate_fleet(ep, est, serving=serving)  # warm the SPMD program
    with stopwatch() as sw_shd:
        shd = estimate_fleet(ep, est, serving=serving)
    dt_shd = sw_shd.seconds
    close = bool(np.allclose(shd, base, rtol=1e-4, atol=1e-3))
    # the int8 serving stack (fused featurize + quantized weights): same
    # sharded per-period program, int8 LSTM/FC contractions
    kw8 = dict(serving=serving, quant="int8", fused=True)
    shd8 = estimate_fleet(ep, est, **kw8)  # warm
    with stopwatch() as sw_shd8:
        shd8 = estimate_fleet(ep, est, **kw8)
    dt_shd8 = sw_shd8.seconds
    # int8 weights vs fp32 weights on identical inputs: the quantization
    # error seen by the controllers, in Mbps
    int8_dev = float(np.sqrt(np.mean((np.asarray(shd8, float)
                                      - np.asarray(shd, float)) ** 2)))
    # real-time capacity: UEs one chip sustains at one fleet predict per
    # REPORT_PERIOD_S (linear-in-N extrapolation from the measured period)
    cap_chip = n * (REPORT_PERIOD_S / (dt_shd / T)) / serving.n_chips
    cap_chip8 = n * (REPORT_PERIOD_S / (dt_shd8 / T)) / serving.n_chips
    out = {"n": n, "rate": n * T / dt_shd, "rate_unsharded": n * T / dt_base,
           "ue_capacity_per_chip": cap_chip, "allclose": close,
           "ue_capacity_per_chip_int8": cap_chip8,
           "int8_capacity_ratio": cap_chip8 / cap_chip,
           "int8_serving_rmse_mbps": int8_dev,
           "int8_ok": int8_dev < 1.0}
    record(f"mesh/n{n}", t0,
           f"mesh={serving.describe()};chips={serving.n_chips};"
           f"ue_steps_per_sec={out['rate']:.0f};"
           f"unsharded_ue_steps_per_sec={out['rate_unsharded']:.0f};"
           f"ue_capacity_per_chip={cap_chip:.0f};"
           f"ue_capacity_per_chip_int8={cap_chip8:.0f};"
           f"int8_capacity_ratio={cap_chip8 / cap_chip:.2f};"
           f"int8_serving_rmse_mbps={int8_dev:.3f};allclose={close}")
    return out


def run_mesh(state: dict, mesh_spec: str, sizes=None,
             T: int | None = None) -> bool:
    """Estimator-serving sweep under a host mesh + the regression pins."""
    t0 = time.time()
    prof = _vgg_profile(state)
    table, cfg, fixed = fig6_adaptive.fig6_table(prof)
    # the serving path must not disturb either standing guarantee: engine
    # vs looped (fig6) and the sched=None bit-identical no-op pin
    ok_eq = check_fig6_equivalence(prof, table, cfg, fixed, t0)
    ok_noop = check_cells_equivalence(prof, table, cfg, fixed, t0)
    serving = make_serving_mesh(mesh_spec)
    est = mesh_estimator()
    sizes = sizes or ([64, 256] if FAST else [64, 256, 1024])
    T = T or (10 if FAST else 30)
    rng = np.random.default_rng(7)
    cells = [mesh_sweep_cell(n, T, est, serving, rng, t0) for n in sizes]
    ok_close = all(c["allclose"] for c in cells)
    ok_int8 = all(c["int8_ok"] for c in cells)
    int8_eval = int8_table2_eval(est, rng, t0)
    # composition: the engine scan consuming the mesh-sharded estimates
    n0 = sizes[0]
    grid, _ = scenario_grid(n0, T, rng)
    ep = gen_episode_batch(grid, T, rng, include_iq=True, n_sc=est[0].n_sc)
    res = simulate_fleet(ep, table, prof, cfg, estimator=est,
                         serving=serving, fixed_split=fixed)
    record("mesh/engine_compose", t0,
           f"n={n0};mesh={serving.describe()};"
           f"delay_ms={res.delay_s.mean()*1e3:.0f};"
           f"energy_J={res.energy_j.mean():.2f};"
           f"privacy={res.privacy.mean():.3f}")
    state["mesh"] = {"spec": serving.describe(), "chips": serving.n_chips,
                     "cells": cells, "int8_table2": int8_eval}
    record("mesh/claims", t0,
           f"fig6_equivalence={ok_eq};sched_noop_identical={ok_noop};"
           f"sharded_allclose={ok_close};int8_rmse_pinned={ok_int8};"
           f"int8_table2_delta_mbps={int8_eval['rmse_delta_mbps']:.3f};"
           f"mesh={serving.describe()};max_fleet={max(sizes)}")
    return ok_eq and ok_noop and ok_close and ok_int8 and int8_eval["ok"]


CHURN_OCCUPANCY = 0.85  # Little's-law occupancy target of the churn sweep


def churn_sessions(schedule: ChurnSchedule, rng) -> object:
    """One lean episode row per scheduled session (scenarios cycle S0-S3,
    traces only as long as the longest dwell; KPM/IQ synthesis skipped —
    the slot-pool sweep drives controllers on ground truth, and tens of
    thousands of short sessions must not materialize gigabytes)."""
    m = schedule.n_sessions
    scen = np.asarray(SCENARIOS, object)[np.arange(m) % len(SCENARIOS)]
    return gen_episode_batch(scen, schedule.max_dwell, rng,
                             include_iq=False, include_kpms=False)


def check_churn_full_pool(prof, table, cfg, fixed, t0) -> bool:
    """The degenerate schedule (every session arrives at t=0, dwells the
    whole horizon, capacity = sessions) through the slot pool must match
    the batch engine: bit-identical splits, float-identical metrics — the
    pool is a strict generalisation, not a parallel implementation."""
    rng = np.random.default_rng(5)
    n, T = 32, 20
    grid, _ = scenario_grid(n, T, rng)
    ep = gen_episode_batch(grid, T, rng, include_iq=False)
    base = simulate_fleet(ep, table, prof, cfg, fixed_split=fixed)
    schedule = ChurnSchedule(arrival_t=np.zeros(n, np.int32),
                             dwell=np.full(n, T, np.int32),
                             ready_end=np.full(T, n, np.int32),
                             horizon=T, max_admits=n)
    pool = simulate_fleet(ep, table, prof, cfg, churn=schedule, capacity=n,
                          fixed_split=fixed)
    splits_eq = (np.array_equal(base.splits, pool.splits)
                 and bool(pool.active.all()))
    mdev = max(float(np.abs(getattr(base, f) - getattr(pool, f)).max())
               for f in ("true_tp", "est_tp", "delay_s", "privacy",
                         "energy_j"))
    ok = splits_eq and mdev < 1e-9
    record("churn/full_pool_equivalence", t0,
           f"splits_identical={splits_eq};metrics_max_absdev={mdev:.1e};"
           f"ok={ok}")
    return ok


def churn_cell(n_slots: int, frac: float, T: int, prof, table, cfg, fixed,
               rng, t0) -> dict:
    """One (capacity, churn-fraction) point: ``frac * capacity`` UEs
    arrive per period (diurnal tide on top), dwell times sized by Little's
    law for ~``CHURN_OCCUPANCY`` steady-state occupancy."""
    ccfg = ChurnConfig(arrival_rate=frac * n_slots,
                       mean_dwell=max(1.0, CHURN_OCCUPANCY / frac),
                       diurnal_amplitude=0.25, diurnal_period=T)
    schedule = make_churn_schedule(ccfg, T, rng)
    sessions = churn_sessions(schedule, rng)
    kw = dict(churn=schedule, capacity=n_slots, fixed_split=fixed)
    with stopwatch() as sw_warm:
        simulate_fleet(sessions, table, prof, cfg, **kw)  # warm the pool
    dt_warm = sw_warm.seconds
    sweep = pool_programs(cfg.ewma_alpha, cfg.hysteresis_steps,
                          cfg.fallback_split, None, 1,
                          int(schedule.max_admits)).sweep
    n_traces = getattr(sweep, "_cache_size", lambda: None)()
    with stopwatch() as sw:
        res = simulate_fleet(sessions, table, prof, cfg, **kw)
    dt = sw.seconds
    if n_traces is not None:  # compile-count assertion: churn, no retrace
        no_retrace = sweep._cache_size() == n_traces
    else:  # jax without _cache_size: a retrace would re-pay compilation
        no_retrace = dt < 0.5 * dt_warm
    lc = res.lifecycle
    rate = lc.ue_steps / dt
    p99 = lc.p99_admit_latency()
    occ = float(lc.occupancy.mean()) / n_slots
    out = {"n_slots": n_slots, "churn_frac": frac, "rate": rate,
           "p99_admit_periods": p99, "occupancy": occ,
           "n_sessions": lc.n_sessions, "n_admitted": lc.n_admitted,
           "no_retrace": bool(no_retrace)}
    record(f"churn/s{n_slots}_f{int(round(frac * 100))}", t0,
           f"ue_steps_per_sec={rate:.0f};p99_admit_latency_periods={p99:.1f};"
           f"occupancy={occ:.2f};sessions={lc.n_sessions};"
           f"admitted={lc.n_admitted};departed={int(lc.departed.sum())};"
           f"no_retrace={bool(no_retrace)}")
    return out


def run_churn(state: dict, sizes=None, fracs=None,
              T: int | None = None) -> bool:
    """The slot-pool churn sweep + the full-pool equivalence pin."""
    t0 = time.time()
    prof = _vgg_profile(state)
    table, cfg, fixed = fig6_adaptive.fig6_table(prof)
    sizes = sizes or ([256] if FAST else [1024, 4096])
    fracs = fracs or ([0.1, 0.25] if FAST else [0.1, 0.25, 0.5])
    T = T or (20 if FAST else 40)
    ok_eq = check_churn_full_pool(prof, table, cfg, fixed, t0)
    rng = np.random.default_rng(17)
    cells = [churn_cell(s, f, T, prof, table, cfg, fixed, rng, t0)
             for s in sizes for f in fracs]
    state["churn"] = cells
    ok_retrace = all(c["no_retrace"] for c in cells)
    ok_occupied = all(c["occupancy"] > 0.3 for c in cells)
    record("churn/claims", t0,
           f"full_pool_equivalence={ok_eq};no_retrace={ok_retrace};"
           f"occupancy_sane={ok_occupied};max_slots={max(sizes)};"
           f"max_churn_frac={max(fracs)}")
    return ok_eq and ok_retrace and ok_occupied


# ------------------------------------------------------------- telemetry
def _tiny_estimator():
    """Minimal estimator for the telemetry smoke (random weights: the
    smoke measures observability overhead and event plumbing, not
    accuracy — and an untrained estimator's RMSE reliably trips the
    drift monitor, which is exactly what the event-timeline cell
    wants)."""
    import jax

    from repro.estimator.model import EstimatorConfig, init_estimator
    e = EstimatorConfig(n_sc=16, lstm_hidden=8, hidden=8)
    return e, init_estimator(e, jax.random.PRNGKey(0))


def telemetry_cell(n_slots: int, T: int, est, prof, table, cfg, fixed, rng,
                   t0) -> dict:
    """One capacity point: the estimator-driven churn run with the metric
    plane on vs off — splits/estimates bit-identical, wall-clock overhead
    bounded, and zero additional retraces (the telemetry sweep is its own
    compiled program; churning the population must never recompile it)."""
    frac = 0.25
    ccfg = ChurnConfig(arrival_rate=frac * n_slots,
                       mean_dwell=max(1.0, CHURN_OCCUPANCY / frac),
                       diurnal_amplitude=0.25, diurnal_period=T)
    schedule = make_churn_schedule(ccfg, T, rng)
    m = schedule.n_sessions
    scen = np.asarray(SCENARIOS, object)[np.arange(m) % len(SCENARIOS)]
    sessions = gen_episode_batch(scen, schedule.max_dwell, rng,
                                 n_sc=est[0].n_sc)
    tcfg = TelemetryConfig(events_capacity=8192)
    kw = dict(churn=schedule, capacity=n_slots, estimator=est,
              fixed_split=fixed)
    base = simulate_fleet(sessions, table, prof, cfg, **kw)  # warm off
    tele = simulate_fleet(sessions, table, prof, cfg, telemetry=tcfg, **kw)
    identical = (np.array_equal(base.splits, tele.splits)
                 and np.array_equal(base.est_tp, tele.est_tp))
    sweep = pool_programs(cfg.ewma_alpha, cfg.hysteresis_steps,
                          cfg.fallback_split, None, 1,
                          int(schedule.max_admits), telem=tcfg).sweep
    n_traces = getattr(sweep, "_cache_size", lambda: None)()
    reps = 2 if FAST else 3
    off = _best_of(lambda: simulate_fleet(sessions, table, prof, cfg, **kw),
                   reps=reps)
    on = _best_of(lambda: simulate_fleet(sessions, table, prof, cfg,
                                         telemetry=tcfg, **kw), reps=reps)
    no_retrace = (sweep._cache_size() == n_traces if n_traces is not None
                  else True)
    rec = tele.telemetry
    overhead = on.best / off.best
    out = {"n_slots": n_slots, "overhead_x": overhead,
           "run_s_off": off.best, "run_s_on": on.best,
           "run_s_on_median": on.median, "identical": bool(identical),
           "no_retrace": bool(no_retrace),
           "active_steps": rec.active_steps, "admitted": rec.admitted,
           "events": len(rec.events), "dropped_events": rec.dropped_events}
    record(f"telemetry/s{n_slots}", t0,
           f"overhead_x={overhead:.3f};run_s_off={off.best:.2f};"
           f"run_s_on={on.best:.2f};identical={bool(identical)};"
           f"no_retrace={bool(no_retrace)};active_steps={rec.active_steps};"
           f"admitted={rec.admitted};events={len(rec.events)};"
           f"dropped_events={rec.dropped_events}")
    return out


def telemetry_drift_cell(est, prof, table, cfg, fixed, t0):
    """A small churn + online-adaptation cell whose decoded event
    timeline is the committed smoke record: admissions with queue
    latency, departures, drift triggers and adaptation bursts — the run
    health ``tools/fleetmon.py`` renders."""
    rng = np.random.default_rng(3)
    schedule = make_churn_schedule(
        ChurnConfig(arrival_rate=3.0, mean_dwell=6.0), 14, rng)
    m = schedule.n_sessions
    scen = np.asarray(SCENARIOS, object)[np.arange(m) % len(SCENARIOS)]
    sessions = gen_episode_batch(scen, schedule.max_dwell, rng,
                                 n_sc=est[0].n_sc)
    ocfg = OnlineConfig(capacity=256, batch=16, steps=2, min_fill=8,
                        drift=DriftConfig(threshold_mbps=0.1,
                                          calibrate_periods=2, patience=1,
                                          cooldown=2))
    res = simulate_fleet(sessions, table, prof, cfg, churn=schedule,
                         capacity=12, estimator=est, online=ocfg,
                         telemetry=TelemetryConfig())
    rec = res.telemetry
    kinds = {ev.kind for ev in rec.events}
    ok = {"admit", "depart", "drift_trigger", "burst_end"} <= kinds
    out = {"event_kinds": sorted(kinds), "n_events": len(rec.events),
           "dropped_events": rec.dropped_events, "ok_timeline": ok}
    record("telemetry/drift_timeline", t0,
           f"events={len(rec.events)};"
           f"kinds={'/'.join(sorted(kinds))};"
           f"dropped_events={rec.dropped_events};ok={ok}")
    return out, rec


def run_telemetry(state: dict, sizes=None, T: int | None = None) -> bool:
    """The telemetry smoke: overhead/bit-identity/no-retrace gates on the
    estimator-driven churn run, plus the churn+online drift cell whose
    decoded record lands in the JSON for ``tools/fleetmon.py``."""
    t0 = time.time()
    prof = _vgg_profile(state)
    table, cfg, fixed = fig6_adaptive.fig6_table(prof)
    est = _tiny_estimator()
    sizes = sizes or ([256] if FAST else [1024])
    T = T or 20
    rng = np.random.default_rng(17)
    cells = [telemetry_cell(s, T, est, prof, table, cfg, fixed, rng, t0)
             for s in sizes]
    drift, rec = telemetry_drift_cell(est, prof, table, cfg, fixed, t0)
    state["telemetry"] = {"cells": cells, "drift": drift,
                          "record": rec.to_dict()}
    ok_id = all(c["identical"] for c in cells)
    ok_overhead = all(c["overhead_x"] <= 1.05 for c in cells)
    ok_retrace = all(c["no_retrace"] for c in cells)
    ok_events = drift["ok_timeline"]
    record("telemetry/claims", t0,
           f"identical={ok_id};overhead<=1.05x={ok_overhead};"
           f"no_retrace={ok_retrace};drift_timeline={ok_events};"
           f"max_slots={max(sizes)}")
    return ok_id and ok_overhead and ok_retrace and ok_events


# --------------------------------------------------------------- profile
def _best_of(fn, reps: int = 2):
    """Best/median/spread wall time of ``fn()`` as a
    ``repro.sim.telemetry.StageStat``. Call once beforehand to warm jit
    caches; best filters scheduler noise on small CI hosts, and the
    median + spread land in the ``--profile`` record so a noisy host is
    visible in the evidence rather than silently flattering it."""
    return timed(fn, reps=reps)


def profile_cell(n: int, T: int, est, prof, table, cfg, fixed, rng,
                 t0) -> dict:
    """Per-stage wall-time breakdown of the per-period fleet step at one
    fleet size: featurize / estimator forward / PSO query (controller
    scan) / scheduler scan / load coupling, each in its unfused (PR 6) and
    fused (``repro.kernels``) form, plus the end-to-end estimator-driven
    engine before/after fusing. The numbers are the evidence behind the
    fusion targets — what dominates the 0.1 s report-period budget."""
    import jax
    import jax.numpy as jnp

    from repro.channel import kpm as kpmmod
    from repro.estimator.serve import fwd_int8, quantize_estimator
    from repro.estimator.train import fwd
    from repro.kernels.featurize import kpm_feature_windows
    from repro.sim.cells import coupled_interference_mw
    from repro.sim.engine import (EST_CHUNK_ROWS, run_controllers,
                                  run_scheduled)

    ecfg, params = est
    grid, _ = scenario_grid(n, T, rng)
    ep = gen_episode_batch(grid, T, rng, include_iq=True, n_sc=ecfg.n_sc)
    stages: dict = {}

    # featurize: the host stride-trick window materialization (a ~WINDOWx
    # blowup of the trace) vs the fused device kernel on the same slab
    def host_feat():
        ep.kpm_windows(normalize=True).astype(np.float32)

    kpms_d = jnp.asarray(ep.kpms, jnp.float32)
    center = jnp.asarray(kpmmod.KPM_CENTER)
    scale = jnp.asarray(kpmmod.KPM_SCALE)

    def fused_feat():
        jax.block_until_ready(
            kpm_feature_windows(kpms_d, center, scale, WINDOW))

    host_feat(), fused_feat()  # warm
    stages["featurize_host"] = _best_of(host_feat)
    stages["featurize_fused"] = _best_of(fused_feat)

    # estimator forward: one EST_CHUNK_ROWS-row dispatch, fp32 vs int8
    # (exactly the rows the engine's chunked estimate_fleet builds)
    wins = ep.kpm_windows(normalize=True).astype(np.float32)
    b = max(1, min(T, EST_CHUNK_ROWS // max(n, 1)))
    kpms_rows = jnp.asarray(np.ascontiguousarray(wins[:, :b]).reshape(
        n * b, *wins.shape[2:]))
    iq_rows = jnp.asarray(np.asarray(ep.iq[:, :b], np.float32).reshape(
        n * b, *ep.iq.shape[2:]))
    alloc_rows = jnp.asarray(np.repeat(ep.alloc_ratio.astype(np.float32), b))
    qparams = quantize_estimator(params, use_kernel=False)

    def f32_fwd():
        jax.block_until_ready(
            fwd(ecfg, params, kpms_rows, iq_rows, alloc_rows))

    def int8_fwd():  # oracle form: what compiles under a serving mesh
        jax.block_until_ready(
            fwd_int8(ecfg, qparams, kpms_rows, iq_rows, alloc_rows,
                     use_kernel=False))

    f32_fwd(), int8_fwd()
    stages["estimator_fwd"] = _best_of(f32_fwd)
    stages["estimator_fwd_int8"] = _best_of(int8_fwd)

    # PSO query: the controller scan gathering each UE's lookup row
    tables = np.broadcast_to(table.table, (n, len(table.table)))
    est_tp = np.asarray(ep.tp_mbps, np.float32)
    true_tp = np.asarray(ep.tp_mbps, float)

    def pso():
        run_controllers(tables, est_tp, cfg, fixed)

    # scheduler scan (controllers + gNB PRB scheduler in one lax.scan):
    # XLA scatter segment ops vs the fused segsum kernel
    n_cells = 4
    cell_idx = np.repeat((np.arange(n) % n_cells)[:, None], T, axis=1)

    def sched(fused):
        run_scheduled(tables, est_tp, cfg, fixed,
                      SchedulerConfig("pf", fused=fused), n_cells,
                      cell_idx, true_tp)

    pso(), sched(False), sched(True)
    stages["pso_query"] = _best_of(pso)
    stages["sched_scan"] = _best_of(lambda: sched(False))
    stages["sched_scan_fused"] = _best_of(lambda: sched(True))

    # (C, C) load coupling: host one-hot reduction vs the segsum kernel
    cgrid = handover_grid(attach_ring(n, n_cells), T + WINDOW, 0.25, rng,
                          n_cells=n_cells)
    dem = rng.uniform(0.05, 1.0, n)
    coup = ring_coupling(n_cells)

    def coupling(k):
        coupled_interference_mw(cgrid, dem, coup, use_kernel=k)

    coupling(False), coupling(True)
    stages["coupling_host"] = _best_of(lambda: coupling(False))
    stages["coupling_fused"] = _best_of(lambda: coupling(True))

    # end-to-end: the estimator-driven engine, before vs after fusing
    kw = dict(estimator=est, fixed_split=fixed)
    simulate_fleet(ep, table, prof, cfg, **kw)  # warm
    simulate_fleet(ep, table, prof, cfg, fused=True, **kw)
    with stopwatch() as sw_u:
        res_u = simulate_fleet(ep, table, prof, cfg, **kw)
    dt_u = sw_u.seconds
    with stopwatch() as sw_f:
        res_f = simulate_fleet(ep, table, prof, cfg, fused=True, **kw)
    dt_f = sw_f.seconds
    close = bool(np.allclose(res_f.est_tp, res_u.est_tp, rtol=1e-4,
                             atol=1e-3))
    out = {"n": n,
           "stages_ms": {k: s.best * 1e3 for k, s in stages.items()},
           "stages_ms_median": {k: s.median * 1e3
                                for k, s in stages.items()},
           "stages_ms_spread": {k: s.spread * 1e3
                                for k, s in stages.items()},
           "rate_unfused": n * T / dt_u, "rate_fused": n * T / dt_f,
           "speedup_fused": dt_u / dt_f, "allclose": close}
    record(f"profile/n{n}", t0,
           ";".join(f"{k}_ms={s.best * 1e3:.1f}" for k, s in stages.items())
           + f";unfused_ue_steps_per_sec={n * T / dt_u:.0f}"
           f";fused_ue_steps_per_sec={n * T / dt_f:.0f}"
           f";fused_speedup_x={dt_u / dt_f:.2f};allclose={close}")
    return out


def profile_ssm_step(n: int, t0) -> dict:
    """O(1)-per-report evidence for the recurrent estimator: the wall
    time of one ``ssm_step`` report after WINDOW vs 4x WINDOW reports of
    history must be flat (the windowed path's featurize + forward re-read
    WINDOW reports every period; the recurrent step has NO featurize
    stage at all — one (N, 16) report row in, constant state updated)."""
    import jax
    import jax.numpy as jnp

    from repro.estimator.ssm import (SSMConfig, init_ssm, ssm_state_init,
                                     ssm_step)
    c = SSMConfig()
    params = init_ssm(c, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    feats = jnp.asarray(rng.normal(size=(n, c.n_feats)), jnp.float32)

    def one_report_after(history: int) -> float:
        state = ssm_state_init(c, (n,))
        for _ in range(history):
            state, _ = ssm_step(c, params, state, feats)
        jax.block_until_ready(state)

        def step():
            jax.block_until_ready(ssm_step(c, params, state, feats)[0])

        step()  # warm (same program for every history length)
        return _best_of(step, reps=3).best

    dt_short = one_report_after(WINDOW)
    dt_long = one_report_after(4 * WINDOW)
    ratio = dt_long / dt_short
    out = {"n": n, "step_ms_after_window": dt_short * 1e3,
           "step_ms_after_4x_window": dt_long * 1e3,
           "history_cost_ratio": ratio,
           "state_bytes_per_ue": c.state_bytes(),
           # generous bound: O(WINDOW) work would show up as ~4x
           "o1_flat": ratio < 2.0}
    record(f"profile/ssm_step_n{n}", t0,
           f"step_ms_after_window={dt_short * 1e3:.2f};"
           f"step_ms_after_4x_window={dt_long * 1e3:.2f};"
           f"history_cost_ratio={ratio:.2f};"
           f"state_bytes_per_ue={c.state_bytes()};"
           f"featurize_stage=none;o1_flat={out['o1_flat']}")
    return out


def _churn_baseline():
    """(best committed churn_smoke rate in UE-steps/s, its machine config)
    — the before-record the fused per-period path is compared against."""
    import json
    path = (pathlib.Path(__file__).resolve().parents[1]
            / "benchmarks" / "results" / "churn_smoke.json")
    try:
        payload = json.loads(path.read_text())
    except (OSError, ValueError):
        return None, {}
    rates = [c["rate"] for c in payload.get("churn") or []]
    return (max(rates) if rates else None), payload.get("config", {})


def run_profile(state: dict, sizes=None, T: int | None = None) -> bool:
    """The per-period hot-path profile: stage breakdown + fused/unfused
    before-after at each fleet size, plus the slot-pool path at scale
    against the committed ``churn_smoke.json`` baseline record."""
    t0 = time.time()
    prof = _vgg_profile(state)
    table, cfg, fixed = fig6_adaptive.fig6_table(prof)
    est = mesh_estimator()
    sizes = sizes or ([256] if FAST else [1024])
    T = T or (10 if FAST else 20)
    rng = np.random.default_rng(7)
    cells = [profile_cell(n, T, est, prof, table, cfg, fixed, rng, t0)
             for n in sizes]
    # the per-period pool path at scale vs the committed baseline record
    base_rate, base_cfg = _churn_baseline()
    slots = 256 if FAST else 4096
    churn = churn_cell(slots, 0.25, 20, prof, table, cfg, fixed, rng, t0)
    ratio = (churn["rate"] / base_rate) if base_rate else None
    record("profile/churn_vs_baseline", t0,
           f"slots={slots};rate={churn['rate']:.0f};"
           f"baseline_rate={(base_rate or 0):.0f};"
           f"baseline_cpu_count={base_cfg.get('cpu_count')};"
           f"speedup_vs_baseline_x={(ratio or 0):.2f}")
    ssm_step_prof = profile_ssm_step(sizes[0], t0)
    state["profile"] = {"cells": cells, "churn": churn,
                        "churn_baseline_rate": base_rate,
                        "churn_speedup_vs_baseline": ratio,
                        "ssm_step": ssm_step_prof}
    ok_close = all(c["allclose"] for c in cells)
    # the speed gates only bind on the full-size run: FAST smokes assert
    # correctness, not machine-dependent timings
    ok_speed = FAST or all(c["speedup_fused"] >= 1.5 for c in cells)
    ok_churn = FAST or ratio is None or ratio >= 1.5
    ok_ssm_o1 = FAST or ssm_step_prof["o1_flat"]
    record("profile/claims", t0,
           f"allclose={ok_close};fused_speedup>=1.5x={ok_speed};"
           f"churn_vs_baseline>=1.5x={ok_churn};"
           f"ssm_step_o1_flat={ok_ssm_o1};"
           f"sizes={'/'.join(str(s) for s in sizes)}")
    return ok_close and ok_speed and ok_churn and ok_ssm_o1


DRIFT_PRE = ("none", "cci")  # the estimator's offline training world
DRIFT_POST = ("jamming", "tdd")  # the unseen regime the fleet drifts into


def drift_grid(n: int, T: int) -> np.ndarray:
    """(N, T + WINDOW) scenario grid realising a distribution shift: every
    UE starts inside the offline training distribution and jumps to an
    unseen interference regime at mid-episode (unlike the fleet sweep's
    quarter-fleet handover, the whole serving distribution moves)."""
    # object dtype: a fixed-width '<U4' grid would truncate "jamming"
    pre = np.asarray(DRIFT_PRE, object)[np.arange(n) % len(DRIFT_PRE)]
    post = np.asarray(DRIFT_POST, object)[np.arange(n) % len(DRIFT_POST)]
    grid = np.repeat(pre[:, None], T + WINDOW, axis=1)
    grid[:, WINDOW + T // 2:] = post[:, None]
    return grid


def online_estimator(n_sc: int, steps: int):
    """Estimator trained OFFLINE on the pre-drift distribution only — the
    paper's train-once regime the drift sweep stresses (reduced widths
    like ``mesh_estimator``: the sweep measures adaptation, not absolute
    accuracy)."""
    from repro.channel.scenarios import gen_dataset
    from repro.estimator.model import EstimatorConfig
    from repro.estimator.train import train_estimator
    e = EstimatorConfig(n_sc=n_sc, lstm_hidden=32, hidden=32)
    rng = np.random.default_rng(0)
    tr = gen_dataset(120 if FAST else 240, rng, scenarios=DRIFT_PRE,
                     episode_len=10, n_sc=n_sc)
    params, _, _ = train_estimator(e, tr, steps=steps, batch=32, seed=0)
    return e, params


def _rmse(res, cols: slice) -> float:
    err = res.est_tp[:, cols] - res.true_tp[:, cols]
    return float(np.sqrt(np.mean(np.asarray(err, float) ** 2)))


def online_cell(n: int, T: int, est, prof, table, cfg, fixed, t0) -> dict:
    """One fleet size through the drift episode: frozen vs online-adapted
    estimator, plus the online=None bit-identity pin."""
    rng = np.random.default_rng(13)
    ep = gen_episode_batch(drift_grid(n, T), T, rng, include_iq=True,
                           n_sc=est[0].n_sc)
    kw = dict(estimator=est, fixed_split=fixed)
    simulate_fleet(ep, table, prof, cfg, **kw)  # warm the jits
    with stopwatch() as sw_frz:
        frozen = simulate_fleet(ep, table, prof, cfg, **kw)
    dt_frz = sw_frz.seconds
    # bit-identity: online=None must BE the PR 4 program
    noop = simulate_fleet(ep, table, prof, cfg, online=None, **kw)
    ok_noop = (np.array_equal(noop.splits, frozen.splits)
               and np.array_equal(noop.est_tp, frozen.est_tp))
    ocfg = OnlineConfig(
        capacity=min(4 * n, 8192), batch=256, steps=25, lr=3e-3,
        min_fill=min(n, 256),
        drift=DriftConfig(alpha=0.5, calibrate_periods=4, ratio=1.5,
                          patience=2, cooldown=2))
    simulate_fleet(ep, table, prof, cfg, online=ocfg, **kw)  # warm the
    # online programs too (ring scatter + burst step), so overhead_x
    # compares steady-state serving, not compiler speed
    with stopwatch() as sw_onl:
        onl = simulate_fleet(ep, table, prof, cfg, online=ocfg, **kw)
    dt_onl = sw_onl.seconds
    pre, post = slice(0, T // 2), slice(T // 2, None)
    out = {"n": n, "rate": n * T / dt_onl, "rate_frozen": n * T / dt_frz,
           "overhead_x": dt_onl / dt_frz, "ok_noop": ok_noop,
           "rmse_pre_frozen": _rmse(frozen, pre),
           "rmse_post_frozen": _rmse(frozen, post),
           "rmse_pre_online": _rmse(onl, pre),
           "rmse_post_online": _rmse(onl, post),
           "n_adaptations": onl.online.n_adaptations,
           "train_steps": onl.online.train_steps}
    out["beats_frozen"] = out["rmse_post_online"] < out["rmse_post_frozen"]
    record(f"online/n{n}", t0,
           f"ue_steps_per_sec={out['rate']:.0f};"
           f"frozen_ue_steps_per_sec={out['rate_frozen']:.0f};"
           f"overhead_x={out['overhead_x']:.2f};"
           f"rmse_pre_frozen={out['rmse_pre_frozen']:.1f};"
           f"rmse_post_frozen={out['rmse_post_frozen']:.1f};"
           f"rmse_post_online={out['rmse_post_online']:.1f};"
           f"n_adaptations={out['n_adaptations']};"
           f"train_steps={out['train_steps']};"
           f"delay_ms={onl.delay_s.mean()*1e3:.0f};"
           f"energy_J={onl.energy_j.mean():.2f};"
           f"privacy={onl.privacy.mean():.3f};"
           f"beats_frozen={out['beats_frozen']};noop_identical={ok_noop}")
    return out


def run_online(state: dict, sizes=None, T: int | None = None) -> bool:
    """The drift sweep: frozen vs drift-triggered online adaptation."""
    t0 = time.time()
    prof = _vgg_profile(state)
    table, cfg, fixed = fig6_adaptive.fig6_table(prof)
    n_sc = 32 if FAST else 64
    est = online_estimator(n_sc, steps=400 if FAST else 600)
    sizes = sizes or ([256] if FAST else [1024])
    T = T or (20 if FAST else 40)
    cells = [online_cell(n, T, est, prof, table, cfg, fixed, t0)
             for n in sizes]
    state["online"] = cells
    ok_noop = all(c["ok_noop"] for c in cells)
    ok_beat = all(c["beats_frozen"] for c in cells)
    ok_adapt = all(c["n_adaptations"] > 0 for c in cells)
    record("online/claims", t0,
           f"noop_identical={ok_noop};online_beats_frozen={ok_beat};"
           f"adaptations_ran={ok_adapt};max_fleet={max(sizes)};"
           f"drift={'/'.join(DRIFT_PRE)}->{'/'.join(DRIFT_POST)}")
    return ok_noop and ok_beat and ok_adapt


# ------------------------------------------------- SSM online head-to-head
SSM_FORECAST_K = 3  # K-period forecast variant of the head-to-head


def ssm_online_estimator(steps: int, n_sc: int):
    """Recurrent estimator trained offline on the pre-drift distribution
    (teacher-forced sequence training, ``estimator.train.train_ssm``) —
    the SSM twin of :func:`online_estimator`, same train-once regime and
    the same information set (``include_iq=True``: the per-period IQ
    snapshot as instantaneous summary channels)."""
    from repro.estimator.ssm import SSMConfig, episode_features
    from repro.estimator.train import train_ssm
    c = SSMConfig(include_iq=True)
    rng = np.random.default_rng(0)
    n_eps = 48 if FAST else 96
    scen = np.asarray(DRIFT_PRE, object)[np.arange(n_eps) % len(DRIFT_PRE)]
    ep = gen_episode_batch(scen, 20, rng, include_iq=True, n_sc=n_sc)
    data = {"feats": episode_features(ep.kpms, ep.alloc_ratio, ep.iq),
            "tp": np.asarray(ep.tp_mbps, np.float32)}
    params, _, _ = train_ssm(c, data, steps=steps, batch=32, lr=3e-3, seed=0)
    return c, params


def _lstm_serving_bytes_per_ue(e) -> int:
    """Per-UE estimator inputs one report period re-reads on the windowed
    path: the (WINDOW, 15) KPM window plus the (2, n_sc, 14) IQ
    spectrogram, f32 — the footprint the SSM's constant state replaces."""
    return (WINDOW * 15 + 2 * e.n_sc * 14) * 4


def _family_cell(name: str, est, ep, ocfg, prof, table, cfg, fixed,
                 pre: slice, post: slice) -> dict:
    """Frozen + online runs of one estimator family on a shared episode."""
    n, T = ep.n_ues, ep.n_steps
    kw = dict(estimator=est, fixed_split=fixed)
    simulate_fleet(ep, table, prof, cfg, **kw)  # warm
    with stopwatch() as sw_frz:
        frozen = simulate_fleet(ep, table, prof, cfg, **kw)
    dt_frz = sw_frz.seconds
    simulate_fleet(ep, table, prof, cfg, online=ocfg, **kw)  # warm
    with stopwatch() as sw_onl:
        onl = simulate_fleet(ep, table, prof, cfg, online=ocfg, **kw)
    dt_onl = sw_onl.seconds
    return {"rate": n * T / dt_onl, "rate_frozen": n * T / dt_frz,
            "rmse_pre_frozen": _rmse(frozen, pre),
            "rmse_post_frozen": _rmse(frozen, post),
            "rmse_pre_online": _rmse(onl, pre),
            "rmse_post_online": _rmse(onl, post),
            "n_adaptations": onl.online.n_adaptations,
            "train_steps": onl.online.train_steps}


def online_ssm_cell(n: int, T: int, lstm, ssm, prof, table, cfg, fixed,
                    t0) -> dict:
    """One fleet size through the SAME drift episode for both families,
    plus the forecast variant and the persistence floor."""
    import dataclasses

    from repro.estimator.baselines import persistence_rmse
    rng = np.random.default_rng(13)
    ep = gen_episode_batch(drift_grid(n, T), T, rng, include_iq=True,
                           n_sc=lstm[0].n_sc)
    pre, post = slice(0, T // 2), slice(T // 2, None)
    # shared monitor, tighter ratio than the plain --online sweep: the
    # IQ-aware recurrent family degrades far less under this drift (it
    # sees jamming directly), so 1.5x the calibrated baseline would
    # rarely arm for it — 1.2x catches the smaller, real error growth
    # both families show while staying above pre-drift noise
    ocfg = OnlineConfig(
        capacity=min(4 * n, 8192), batch=256, steps=25, lr=3e-3,
        min_fill=min(n, 256),
        drift=DriftConfig(alpha=0.5, calibrate_periods=4, ratio=1.2,
                          patience=2, cooldown=2))
    # wall-clock-matched adaptation budgets, not step-matched: one SSM
    # replay step trains on feature rows through the O(1) recurrence
    # (~an order of magnitude cheaper than the LSTM's window re-read +
    # CNN step, cf. the serving rates in this record), so the same
    # burst wall-time buys a 6x longer schedule
    ocfg_ssm = dataclasses.replace(ocfg, steps=6 * ocfg.steps)
    out = {"n": n,
           "state_bytes_per_ue_ssm": ssm[0].state_bytes(),
           "state_bytes_per_ue_lstm": _lstm_serving_bytes_per_ue(lstm[0])}
    for name, est, oc in (("lstm", lstm, ocfg), ("ssm", ssm, ocfg_ssm)):
        out[name] = _family_cell(name, est, ep, oc, prof, table, cfg,
                                 fixed, pre, post)
    # the K-period forecast variant shares the trained SSM weights: only
    # the (config-static) rollout horizon and reduce policy change
    c, params = ssm
    cfc = dataclasses.replace(c, forecast_horizon=SSM_FORECAST_K,
                              forecast_policy="min")
    est_fc = estimate_fleet(ep, (cfc, params))
    true = np.asarray(ep.tp_mbps, float)
    out["rmse_forecast_min"] = float(np.sqrt(np.mean(
        (est_fc - true) ** 2)))
    out["persistence_floor"] = persistence_rmse(true, horizon=1)
    s, l = out["ssm"], out["lstm"]
    record(f"online_ssm/n{n}", t0,
           f"ssm_ue_steps_per_sec={s['rate']:.0f};"
           f"lstm_ue_steps_per_sec={l['rate']:.0f};"
           f"ssm_rmse_pre={s['rmse_pre_online']:.1f};"
           f"ssm_rmse_post={s['rmse_post_online']:.1f};"
           f"lstm_rmse_pre={l['rmse_pre_online']:.1f};"
           f"lstm_rmse_post={l['rmse_post_online']:.1f};"
           f"ssm_rmse_post_frozen={s['rmse_post_frozen']:.1f};"
           f"lstm_rmse_post_frozen={l['rmse_post_frozen']:.1f};"
           f"ssm_adaptations={s['n_adaptations']};"
           f"lstm_adaptations={l['n_adaptations']};"
           f"adapt_steps_per_burst_lstm={ocfg.steps};"
           f"adapt_steps_per_burst_ssm={ocfg_ssm.steps};"
           f"state_bytes_per_ue_ssm={out['state_bytes_per_ue_ssm']};"
           f"state_bytes_per_ue_lstm={out['state_bytes_per_ue_lstm']};"
           f"rmse_forecast_min_K{SSM_FORECAST_K}="
           f"{out['rmse_forecast_min']:.1f};"
           f"persistence_floor={out['persistence_floor']:.1f}")
    return out


def run_online_ssm(state: dict, sizes=None, T: int | None = None) -> bool:
    """The recurrent-vs-windowed drift head-to-head."""
    t0 = time.time()
    prof = _vgg_profile(state)
    table, cfg, fixed = fig6_adaptive.fig6_table(prof)
    n_sc = 32 if FAST else 64
    lstm = online_estimator(n_sc, steps=400 if FAST else 600)
    # the recurrent trainer needs a longer schedule for parity: each step
    # costs a fraction of the LSTM's (no IQ conv, no window re-reads)
    ssm = ssm_online_estimator(steps=1500 if FAST else 3000, n_sc=n_sc)
    sizes = sizes or ([256] if FAST else [1024])
    T = T or (20 if FAST else 40)
    cells = [online_ssm_cell(n, T, lstm, ssm, prof, table, cfg, fixed, t0)
             for n in sizes]
    state["ssm"] = cells
    ok_adapt = all(c["ssm"]["n_adaptations"] > 0 for c in cells)
    ok_beat_self = all(c["ssm"]["rmse_post_online"]
                       < c["ssm"]["rmse_post_frozen"] for c in cells)
    # state footprint: the constant SSD state must undercut the windowed
    # inputs a period re-reads
    ok_bytes = all(c["state_bytes_per_ue_ssm"]
                   < c["state_bytes_per_ue_lstm"] for c in cells)
    # the head-to-head gate binds on the full run only: FAST smokes
    # assert the loop works, not tiny-budget accuracy ordering
    ok_h2h = FAST or all(c["ssm"]["rmse_post_online"]
                         <= c["lstm"]["rmse_post_online"] for c in cells)
    record("online_ssm/claims", t0,
           f"ssm_adaptations_ran={ok_adapt};"
           f"ssm_online_beats_frozen={ok_beat_self};"
           f"ssm_post_rmse<=lstm={ok_h2h};"
           f"state_bytes_ssm<lstm={ok_bytes};max_fleet={max(sizes)};"
           f"drift={'/'.join(DRIFT_PRE)}->{'/'.join(DRIFT_POST)}")
    return ok_adapt and ok_beat_self and ok_bytes and ok_h2h


def run(state: dict, sizes=None, T: int | None = None) -> bool:
    t0 = time.time()
    prof = _vgg_profile(state)
    # the fig6 configuration, shared so the equivalence check below always
    # exercises exactly what benchmarks/fig6_adaptive.py runs
    table, cfg, fixed = fig6_adaptive.fig6_table(prof)
    sizes = sizes or ([1, 64, 1024] if FAST else [1, 64, 1024, 4096])
    T = T or (30 if FAST else 100)
    ok_eq = check_fig6_equivalence(prof, table, cfg, fixed, t0)
    rng = np.random.default_rng(7)
    cells = [fleet_cell(n, T, prof, table, cfg, fixed, rng, t0,
                        speedup_at=max(sizes)) for n in sizes]
    state["fleet"] = cells
    speedups = [c["speedup"] for c in cells if "speedup" in c]
    ok_speed = bool(speedups) and max(speedups) >= 50.0
    record("fleet/claims", t0,
           f"fig6_equivalence={ok_eq};max_fleet={max(sizes)};"
           f"speedup>=50x={ok_speed}")
    return ok_eq and ok_speed


def main() -> int:
    ap = argparse.ArgumentParser(description="fleet-size sweep")
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: short episodes, sizes 1/64/1024")
    ap.add_argument("--sizes", type=int, nargs="+", default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--cells", type=int, default=0,
                    help="run the multi-cell contended sweep over this many "
                    "load-coupled cells instead of the plain fleet sweep")
    ap.add_argument("--policy", nargs="+", default=None, choices=POLICIES,
                    help="scheduler policies for --cells (default: all)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="run the mesh-sharded estimator-serving sweep on "
                    "a DxM (data x model) or DxExM (x expert) host mesh")
    ap.add_argument("--online", action="store_true",
                    help="run the drift sweep: frozen vs drift-triggered "
                    "online estimator adaptation (repro.sim.online)")
    ap.add_argument("--estimator", default="lstm", choices=["lstm", "ssm"],
                    help="estimator family for --online: the windowed "
                    "LSTM sweep (default), or the recurrent-SSM "
                    "head-to-head against it (repro.estimator.ssm)")
    ap.add_argument("--profile", action="store_true",
                    help="profile the per-period fleet step: per-stage "
                    "wall-time breakdown (featurize/estimator/PSO query/"
                    "scheduler/coupling/ssm_step) plus fused-vs-unfused "
                    "and int8-vs-fp32 before/after records; each stage "
                    "reports best/median/spread over reps")
    ap.add_argument("--telemetry", action="store_true",
                    help="run the telemetry smoke: estimator-driven churn "
                    "with the repro.sim.telemetry metric plane on vs off "
                    "(bit-identity, <=5%% overhead, no-retrace gates) "
                    "plus a churn+online drift cell whose decoded event "
                    "timeline lands in the --json record for "
                    "tools/fleetmon.py")
    ap.add_argument("--churn", action="store_true",
                    help="run the slot-pool churn sweep: continuous UE "
                    "arrival/departure through a fixed-capacity slot pool "
                    "(repro.sim.pool); --sizes sets the pool capacities")
    ap.add_argument("--churn-fracs", type=float, nargs="+", default=None,
                    help="churn fractions (arrivals per period / capacity) "
                    "for --churn (default 0.1 0.25 0.5)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all records + machine/mesh config as "
                    "JSON (comparable across machines)")
    args = ap.parse_args()
    if args.fast:
        import benchmarks.common as common
        common.FAST = True
        global FAST
        FAST = True
    T = args.steps or (30 if (FAST or args.fast) else 100)
    state: dict = {}
    if args.mesh:
        T = args.steps or (10 if (FAST or args.fast) else 30)
        ok = run_mesh(state, args.mesh, sizes=args.sizes, T=T)
        label = "mesh sweep"
    elif args.profile:
        T = args.steps or (10 if (FAST or args.fast) else 20)
        ok = run_profile(state, sizes=args.sizes, T=T)
        label = "profile sweep"
    elif args.online:
        T = args.steps or (20 if (FAST or args.fast) else 40)
        if args.estimator == "ssm":
            ok = run_online_ssm(state, sizes=args.sizes, T=T)
            label = "ssm online head-to-head"
        else:
            ok = run_online(state, sizes=args.sizes, T=T)
            label = "online sweep"
    elif args.telemetry:
        T = args.steps or 20
        ok = run_telemetry(state, sizes=args.sizes, T=T)
        label = "telemetry smoke"
    elif args.churn:
        T = args.steps or (20 if (FAST or args.fast) else 40)
        ok = run_churn(state, sizes=args.sizes, fracs=args.churn_fracs, T=T)
        label = "churn sweep"
    elif args.cells:
        sizes = args.sizes or ([64, 1024] if (FAST or args.fast)
                               else [64, 1024, 4096])
        ok = run_cells(state, args.cells, policies=args.policy, sizes=sizes,
                       T=T)
        label = "cells sweep"
    else:
        sizes = args.sizes or ([1, 64, 1024] if (FAST or args.fast)
                               else [1, 64, 1024, 4096])
        ok = run(state, sizes=sizes, T=T)
        label = "fleet sweep"
    if args.json:
        write_json(args.json, {"mesh": state.get("mesh"),
                               "online": state.get("online"),
                               "ssm": state.get("ssm"),
                               "churn": state.get("churn"),
                               "profile": state.get("profile"),
                               "telemetry": state.get("telemetry"),
                               "ok": ok})
    print(f"# {label} {'OK' if ok else 'FAILED'}", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
