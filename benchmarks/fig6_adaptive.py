"""Fig. 6: Fixed vs Adaptive splitting under S0-S3, three metrics.

Paper headline: under jamming, E2E delay 1657 ms -> 589 ms (64.45% better);
UE-to-BS 37.39%, BS-to-BS 56.67%; no-interference identical; adaptive costs
some extra UE energy. The fixed policy is the no-interference optimum; the
adaptive policy queries the PSO table with the (trained) estimator's
throughput prediction each 0.1s report.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FAST, record
from repro.channel import scenarios as sc
from repro.channel import throughput as tpm
from repro.core.controller import AdaptiveSplitController, ControllerConfig
from repro.core.energy import EDGE_A40X2, UE_VM_2CORE
from repro.core.objective import Constraints, Weights, evaluate
from repro.core.pso import pso_vectorized
from repro.estimator.train import predict

SCEN_LABEL = {"none": "No Interference", "jamming": "Jamming (S1)",
              "cci": "UE-to-BS Int. (S2)", "tdd": "BS-to-BS Int. (S3)"}
PAPER_DELAY_GAIN = {"jamming": 64.45, "cci": 37.39, "tdd": 56.67}

# interference operating points per scenario (dBm at gNB), calibrated to the
# paper's throughput regime: jamming ~8-9 Mbps, CCI ~16 Mbps, TDD ~10 Mbps
SCEN_INT = {"none": -60.0, "jamming": 8.2, "cci": 5.0, "tdd": 7.5}


def _metrics_at(prof, l0, tp_mbps):
    terms = evaluate(prof, UE_VM_2CORE, EDGE_A40X2,
                     np.array([tp_mbps * 1e6]), Weights(1, 0, 0),
                     Constraints())
    return (float(terms.d_e2e[l0, 0]), float(prof.privacy[l0]),
            float(terms.e_ue[l0]))


def run(state: dict) -> None:
    t0 = time.time()
    prof = state["vgg_profile"]
    w = Weights(1.0, 0.15, 0.1)
    cons = Constraints(rho_max=0.92, tau_max_s=6.0, e_max_j=40.0)
    table = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2, w, cons, 130)
    fixed_split = table.query(float(tpm.max_throughput_mbps(
        np.array(SCEN_INT["none"]))))
    est = state.get("estimator")  # (cfg, params) from table2, or None
    rng = np.random.default_rng(123)
    T = 30 if FAST else 80
    load = 0.12  # low UL load: the regime where KPMs alone fail
    summary = {}
    for scen, int_dbm in SCEN_INT.items():
        trace = np.clip(int_dbm + rng.normal(0, 1.0, T + sc.WINDOW), -60, 14)
        if scen == "none":
            trace[:] = -60.0
        # KPM reports along the ACTUAL trace (rolling estimator windows)
        from repro.channel import iq as iqmod
        from repro.channel.kpm import kpm_window, normalize_kpms
        kpms_all = normalize_kpms(kpm_window(trace, load, rng, scen))
        ctl = AdaptiveSplitController(table, ControllerConfig(
            ewma_alpha=0.6, hysteresis_steps=2, fallback_split=fixed_split))
        # warm start: the AF streams reports continuously before this window
        ctl.current_split = fixed_split
        fixed_acc, adap_acc = [], []
        for t in range(sc.WINDOW, sc.WINDOW + T):
            true_tp = float(tpm.max_throughput_mbps(np.array(trace[t])))
            if est is not None:
                ecfg, eparams = est
                iq = iqmod.spectrogram(float(trace[t]), scen, load, rng,
                                       n_sc=ecfg.n_sc)
                data = {"kpms": kpms_all[None, t - sc.WINDOW:t],
                        "iq": iq[None].astype(np.float32),
                        "alloc": np.array([load], np.float32),
                        "tp": np.array([0.0], np.float32)}
                est_tp = float(np.clip(predict(ecfg, eparams, data)[0],
                                       1.0, 130.0))
            else:
                est_tp = true_tp
            l_adap = ctl.update(est_tp)
            fixed_acc.append(_metrics_at(prof, fixed_split, true_tp))
            adap_acc.append(_metrics_at(prof, l_adap, true_tp))
        fx = np.mean(fixed_acc, axis=0)
        ad = np.mean(adap_acc, axis=0)
        gain = 100.0 * (fx[0] - ad[0]) / max(fx[0], 1e-9)
        summary[scen] = (fx, ad, gain)
        record(f"fig6/{scen}", t0,
               f"fixed_ms={fx[0]*1e3:.0f};adaptive_ms={ad[0]*1e3:.0f};"
               f"delay_gain_pct={gain:.1f};paper_gain_pct="
               f"{PAPER_DELAY_GAIN.get(scen, 0.0)};"
               f"privacy_fixed={fx[1]:.3f};privacy_adapt={ad[1]:.3f};"
               f"energy_fixed_J={fx[2]:.2f};energy_adapt_J={ad[2]:.2f}")
    ok_none = abs(summary["none"][2]) < 1.0
    ok_jam = summary["jamming"][2] > 40.0
    ok_energy = all(summary[s][1][2] >= summary[s][0][2] - 1e-9
                    for s in ("jamming", "cci", "tdd"))
    record("fig6/claims", t0,
           f"no_interference_identical={ok_none};"
           f"jamming_gain>40pct={ok_jam};"
           f"adaptive_trades_energy={ok_energy}")
    state["fig6"] = summary
