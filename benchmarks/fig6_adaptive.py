"""Fig. 6: Fixed vs Adaptive splitting under S0-S3, three metrics.

Paper headline: under jamming, E2E delay 1657 ms -> 589 ms (64.45% better);
UE-to-BS 37.39%, BS-to-BS 56.67%; no-interference identical; adaptive costs
some extra UE energy. The fixed policy is the no-interference optimum; the
adaptive policy queries the PSO table with the (trained) estimator's
throughput prediction each 0.1s report.

Runs on the ``repro.sim`` fleet engine: all four scenarios advance as one
vectorized 4-UE fleet (one controller row per scenario), with the whole
fleet's throughput estimates coming from a single ``predict`` call per
report period.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FAST, record
from repro.channel import iq as iqmod
from repro.channel import scenarios as sc
from repro.channel import throughput as tpm
from repro.core.controller import ControllerConfig
from repro.core.energy import EDGE_A40X2, UE_VM_2CORE
from repro.core.objective import Constraints, Weights
from repro.core.pso import pso_vectorized
from repro.sim import simulate_fleet

SCEN_LABEL = {"none": "No Interference", "jamming": "Jamming (S1)",
              "cci": "UE-to-BS Int. (S2)", "tdd": "BS-to-BS Int. (S3)"}
PAPER_DELAY_GAIN = {"jamming": 64.45, "cci": 37.39, "tdd": 56.67}

# interference operating points per scenario (dBm at gNB), calibrated to the
# paper's throughput regime: jamming ~8-9 Mbps, CCI ~16 Mbps, TDD ~10 Mbps
SCEN_INT = {"none": -60.0, "jamming": 8.2, "cci": 5.0, "tdd": 7.5}


def fig6_table(prof):
    """The fig6 operating configuration: PSO table, controller config, and
    the fixed policy (the no-interference optimum). Shared with
    benchmarks/fleet so its equivalence check always exercises the exact
    configuration this figure runs."""
    w = Weights(1.0, 0.15, 0.1)
    cons = Constraints(rho_max=0.92, tau_max_s=6.0, e_max_j=40.0)
    table = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2, w, cons, 130)
    fixed_split = table.query(float(tpm.max_throughput_mbps(
        np.array(SCEN_INT["none"]))))
    cfg = ControllerConfig(ewma_alpha=0.6, hysteresis_steps=2,
                           fallback_split=fixed_split)
    return table, cfg, fixed_split


def fig6_episode(rng: np.random.Generator, T: int, load: float,
                 n_sc: int | None) -> sc.EpisodeBatch:
    """The fig6 operating points as one 4-UE episode: each scenario's trace
    is noise around its fixed interference level (the 'none' row pinned at
    the floor). ``n_sc=None`` skips IQ synthesis (no estimator)."""
    scen = np.array(list(SCEN_INT))
    traces = np.array([np.clip(x + rng.normal(0, 1.0, T + sc.WINDOW), -60, 14)
                       for x in SCEN_INT.values()])
    traces[0, :] = -60.0
    return sc.gen_episode_batch(
        scen, T, rng, load_ratio=load, int_dbm=traces,
        include_iq=n_sc is not None, n_sc=n_sc or iqmod.N_SC)


def run(state: dict) -> None:
    t0 = time.time()
    prof = state["vgg_profile"]
    table, cfg, fixed_split = fig6_table(prof)
    est = state.get("estimator")  # (cfg, params) from table2, or None
    rng = np.random.default_rng(123)
    T = 30 if FAST else 80
    load = 0.12  # low UL load: the regime where KPMs alone fail
    episode = fig6_episode(rng, T, load, est[0].n_sc if est else None)
    # warm start: the AF streams reports continuously before this window
    res = simulate_fleet(episode, table, prof, cfg, warm_split=fixed_split,
                         estimator=est, fixed_split=fixed_split)
    adapt = res.scenario_means(episode.scenario_idx)
    fixed = res.fixed.scenario_means(episode.scenario_idx)
    summary = {}
    for scen in SCEN_INT:
        fx, ad = fixed[scen], adapt[scen]
        gain = 100.0 * (fx[0] - ad[0]) / max(fx[0], 1e-9)
        summary[scen] = (fx, ad, gain)
        record(f"fig6/{scen}", t0,
               f"fixed_ms={fx[0]*1e3:.0f};adaptive_ms={ad[0]*1e3:.0f};"
               f"delay_gain_pct={gain:.1f};paper_gain_pct="
               f"{PAPER_DELAY_GAIN.get(scen, 0.0)};"
               f"privacy_fixed={fx[1]:.3f};privacy_adapt={ad[1]:.3f};"
               f"energy_fixed_J={fx[2]:.2f};energy_adapt_J={ad[2]:.2f}")
    ok_none = abs(summary["none"][2]) < 1.0
    ok_jam = summary["jamming"][2] > 40.0
    ok_energy = all(summary[s][1][2] >= summary[s][0][2] - 1e-9
                    for s in ("jamming", "cci", "tdd"))
    record("fig6/claims", t0,
           f"no_interference_identical={ok_none};"
           f"jamming_gain>40pct={ok_jam};"
           f"adaptive_trades_energy={ok_energy}")
    state["fig6"] = summary
