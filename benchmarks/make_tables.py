"""Render EXPERIMENTS.md tables from dry-run JSONs.

Usage: PYTHONPATH=src python -m benchmarks.make_tables [--perf]
"""
from __future__ import annotations

import json
import pathlib
import sys

DIR = pathlib.Path(__file__).parent / "results" / "dryrun"
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(x, nd=3):
    return f"{x:.{nd}f}" if isinstance(x, (int, float)) else str(x)


def baseline_table() -> str:
    rows = ["| arch | shape | ga | t_compute s | t_memory s | t_collective s"
            " | bottleneck | useful | MFU-bound | fits 16GB | pod2 |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    pod2 = {}
    for f in (DIR / "pod2").glob("*.json"):
        d = json.loads(f.read_text())
        pod2[(d["arch"], d["shape"])] = d["status"]
    for f in sorted((DIR / "pod1").glob("*.json")):
        if f.stem.count("__") != 1:
            continue
        d = json.loads(f.read_text())
        arch, shape = d["arch"], d["shape"]
        p2 = pod2.get((arch, shape), "?")
        if d["status"] != "ok":
            rows.append(f"| {arch} | {shape} | — | — | — | — | "
                        f"{d['status']} | — | — | — | {p2} |")
            continue
        r = d["roofline"]
        rows.append(
            f"| {arch} | {shape} | {d.get('grad_accum')} "
            f"| {fmt(r['t_compute_s'], 4)} | {fmt(r['t_memory_s'], 4)} "
            f"| {fmt(r['t_collective_s'], 4)} | {r['bottleneck']} "
            f"| {fmt(r['useful_flops_ratio'])} | {fmt(r['mfu_bound'])} "
            f"| {r.get('fits_16gb_hbm')} | {p2} |")
    return "\n".join(rows)


def perf_table(stems: list[str]) -> str:
    rows = ["| experiment | t_compute | t_memory | t_collective | bottleneck"
            " | useful | fits |", "|---|---|---|---|---|---|---|"]
    for stem in stems:
        f = DIR / "pod1" / f"{stem}.json"
        if not f.exists():
            rows.append(f"| {stem} | missing | | | | | |")
            continue
        d = json.loads(f.read_text())
        r = d.get("roofline", {})
        if not r:
            rows.append(f"| {stem} | {d['status']} | | | | | |")
            continue
        rows.append(f"| {stem} | {fmt(r['t_compute_s'], 4)} "
                    f"| {fmt(r['t_memory_s'], 4)} "
                    f"| {fmt(r['t_collective_s'], 4)} | {r['bottleneck']} "
                    f"| {fmt(r['useful_flops_ratio'])} "
                    f"| {r.get('fits_16gb_hbm')} |")
    return "\n".join(rows)


if __name__ == "__main__":
    if "--perf" in sys.argv:
        stems = [a for a in sys.argv[1:] if a != "--perf"]
        print(perf_table(stems))
    else:
        print(baseline_table())
