"""Fig. 5: objective landscape F(l, TP) over the 43 VGG16 split points.

5a: E2E-delay-only at 60/30/15 Mbps — minima shift deeper as TP drops, with
    dips at MaxPool outputs.
5b: privacy-only — minima ~0.21-0.22 at splits 25/38/43 (paper-calibrated
    profile + measured dCor on a reduced-width VGG16 for the trend).
5c: energy-only — monotone increasing; minima at the earliest splits.
5d: joint strategies — optimal split vs TP for four weightings.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FAST, record
from repro.core.energy import EDGE_A40X2, UE_VM_2CORE
from repro.core.objective import Constraints, Weights, evaluate
from repro.core.pso import pso_vectorized
from repro.models.vgg import FULL, REDUCED, vgg_split_profile

CONS = Constraints(rho_max=0.98)  # raw input never leaves the UE


def run(state: dict) -> None:
    t0 = time.time()
    prof = vgg_split_profile(FULL)
    state["vgg_profile"] = prof

    # ---- 5a: delay-only minima per throughput
    tps = np.array([60e6, 30e6, 15e6])
    terms = evaluate(prof, UE_VM_2CORE, EDGE_A40X2, tps,
                     Weights(1, 0, 0), CONS)
    d = np.where(prof.privacy[:, None] <= CONS.rho_max, terms.d_e2e, np.inf)
    stars = d.argmin(axis=0) + 1  # 1-based split indices
    pools = [i + 1 for i, n in enumerate(prof.layer_names) if ":pool" in n]
    dips = all(d[p - 1, 1] < d[p - 2, 1] for p in pools[:4])
    record("fig5a/delay_only_minima", t0,
           f"splits_60_30_15Mbps={stars.tolist()};paper=[~7,~14..24,~34];"
           f"maxpool_dips={dips}")

    # ---- 5b: privacy-only
    p = prof.privacy
    order = np.argsort(p)[:3] + 1
    record("fig5b/privacy_minima", t0,
           f"min_splits={sorted(order.tolist())};values="
           f"{[round(float(p[i-1]),3) for i in sorted(order.tolist())]};"
           f"paper=[25,38,43]@0.21-0.22")

    # measured dCor trend on reduced-width VGG16 (real forward passes)
    import jax
    from repro.kernels.dcor import dcor_kernel
    from repro.models.vgg import forward, init_vgg
    n_img = 24 if FAST else 48
    key = jax.random.PRNGKey(0)
    params = init_vgg(REDUCED, key)
    # textured inputs (random frequencies) rather than white noise
    ks = jax.random.split(key, 3)
    base = jax.random.normal(ks[0], (n_img, REDUCED.image_size,
                                     REDUCED.image_size, 3))
    import jax.numpy as jnp
    xs = jnp.cumsum(jnp.cumsum(base, axis=1), axis=2) * 0.05
    acts = forward(REDUCED, params, xs, collect=True)
    sel = [0, 4, 10, 16, 24, 30, 33, 36, 40, 42]
    proj_key = jax.random.PRNGKey(7)
    vals = []
    for i in sel:
        a = acts[i].reshape(n_img, -1)
        if a.shape[1] > 4096:  # random projection preserves dCor trends
            pm = jax.random.normal(proj_key, (a.shape[1], 4096)) / (
                a.shape[1] ** 0.5)
            a = a @ pm
        vals.append(float(dcor_kernel(xs.reshape(n_img, -1), a)))
    decreasing = vals[0] >= vals[-1] and vals[1] >= vals[-2]
    record("fig5b/measured_dcor_reduced_vgg", t0,
           f"splits={[s+1 for s in sel]};dcor={[round(v,3) for v in vals]};"
           f"deep_leaks_less={decreasing}")

    # ---- 5c: energy-only
    e = prof.e_ue(UE_VM_2CORE)
    record("fig5c/energy_monotone", t0,
           f"monotone={bool(np.all(np.diff(e) >= -1e-12))};"
           f"min_splits={list(np.argsort(e)[:3] + 1)};paper=[1,2,3]")

    # ---- 5d: strategies
    strategies = {
        "delay_focused": Weights(1.0, 0.0, 0.0),
        "privacy_focused": Weights(0.2, 1.0, 0.1),
        "energy_focused": Weights(0.2, 0.1, 1.0),
        "joint": Weights(1.0, 0.5, 0.5),
    }
    tables = {}
    for name, w in strategies.items():
        tab = pso_vectorized(prof, UE_VM_2CORE, EDGE_A40X2, w, CONS, 60)
        tables[name] = tab
        picks = {tp: int(tab.table[tp]) + 1 for tp in (5, 15, 30, 60)}
        record(f"fig5d/{name}", t0, f"split_by_tp={picks}")
    state["vgg_tables"] = tables
    dl = tables["delay_focused"].table
    en = tables["energy_focused"].table
    pr = tables["privacy_focused"].table
    record("fig5d/strategy_ordering", t0,
           f"energy_shallower_than_delay={bool(en[30] <= dl[30])};"
           f"privacy_deeper_than_delay={bool(pr[30] >= dl[30])};"
           f"delay_deepens_as_tp_drops={bool(dl[10] >= dl[60])}")
