# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (benchmarks.common.record).
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (fig2_zones, fig5_objective, fig6_adaptive, fleet,
                        roofline, table2_estimator)
from benchmarks.common import emit_header, record


def main() -> None:
    emit_header()
    state: dict = {}
    failures = []
    for mod in (fig2_zones, fig5_objective, table2_estimator, fig6_adaptive,
                fleet, roofline):
        t0 = time.time()
        try:
            mod.run(state)
        except Exception:
            failures.append(mod.__name__)
            traceback.print_exc()
            record(f"{mod.__name__}/ERROR", t0, "see stderr")
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
