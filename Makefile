# Tier-1 verification, wrapped so CI and humans run the same thing.
#   make test   — the repo's tier-1 gate (full pytest suite)
#   make smoke  — quickstart end-to-end (profile -> PSO -> controller -> split)
#   make fleet  — fleet engine smoke (1024 UEs, equivalence + speedup)
#   make ci     — what .github/workflows/ci.yml runs on push
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test smoke fleet ci

test:
	$(PY) -m pytest -x -q

smoke:
	$(PY) examples/quickstart.py --smoke

fleet:
	$(PY) benchmarks/fleet.py --fast

ci: test smoke fleet
