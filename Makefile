# Tier-1 verification, wrapped so CI and humans run the same thing.
#   make test       — the repo's tier-1 gate (full pytest suite)
#   make smoke      — quickstart end-to-end (profile -> PSO -> controller -> split)
#   make fleet      — fleet engine smoke (1024 UEs, equivalence + speedup)
#   make cells      — multi-cell scheduler smoke (64 UEs x 2 cells x 3 policies)
#   make mesh       — mesh-sharded estimator serving smoke (sharded == unsharded)
#   make online     — online-adaptation drift smoke (adapted beats frozen)
#   make ssm        — SSM vs LSTM online head-to-head smoke (O(1) state)
#   make churn      — slot-pool churn smoke (arrival/departure, no retraces)
#   make fused      — fused-path + int8 smoke (profile breakdown, allclose)
#   make telemetry  — telemetry smoke (1024-slot churn, <=5% overhead,
#                     no retrace, drift event timeline -> committed record)
#   make dryrun     — AOT dry-run cell (1 arch x 1 shape on the 256-chip mesh)
#   make docs-check — fail on broken intra-repo links in README/docs
#   make ci         — what .github/workflows/ci.yml runs on push
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test smoke fleet cells mesh online ssm churn fused telemetry \
	dryrun docs-check ci

test:
	$(PY) -m pytest -x -q

smoke:
	$(PY) examples/quickstart.py --smoke

fleet:
	$(PY) benchmarks/fleet.py --fast

cells:
	$(PY) benchmarks/fleet.py --fast --cells 2 --policy rr pf maxsinr \
	  --sizes 64 --steps 10

mesh:
	$(PY) benchmarks/fleet.py --fast --mesh 4x2 --sizes 32 64 --steps 8

online:
	$(PY) benchmarks/fleet.py --fast --online --sizes 128 --steps 20 \
	  --json benchmarks/results/online_smoke.json

ssm:
	$(PY) benchmarks/fleet.py --fast --online --estimator ssm \
	  --json benchmarks/results/ssm_smoke.json

churn:
	$(PY) benchmarks/fleet.py --fast --churn \
	  --json benchmarks/results/churn_smoke.json

fused:
	$(PY) benchmarks/fleet.py --fast --profile --sizes 256 --steps 10 \
	  --json benchmarks/results/fused_smoke.json

telemetry:
	$(PY) benchmarks/fleet.py --fast --telemetry --sizes 1024 --steps 20 \
	  --json benchmarks/results/telemetry_smoke.json
	$(PY) tools/fleetmon.py benchmarks/results/telemetry_smoke.json

dryrun:
	$(PY) -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k \
	  --no-calibrate --force

docs-check:
	$(PY) tools/docs_check.py

ci: test smoke fleet cells mesh online ssm churn fused telemetry dryrun \
	docs-check
