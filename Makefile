# Tier-1 verification, wrapped so CI and humans run the same thing.
#   make test   — the repo's tier-1 gate (full pytest suite)
#   make smoke  — quickstart end-to-end (profile -> PSO -> controller -> split)
#   make fleet  — fleet engine smoke (1024 UEs, equivalence + speedup)
#   make cells  — multi-cell scheduler smoke (64 UEs x 2 cells x 3 policies)
#   make ci     — what .github/workflows/ci.yml runs on push
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test smoke fleet cells ci

test:
	$(PY) -m pytest -x -q

smoke:
	$(PY) examples/quickstart.py --smoke

fleet:
	$(PY) benchmarks/fleet.py --fast

cells:
	$(PY) benchmarks/fleet.py --fast --cells 2 --policy rr pf maxsinr \
	  --sizes 64 --steps 10

ci: test smoke fleet cells
